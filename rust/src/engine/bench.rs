//! The calibrated serving benchmark behind `cascadia bench`:
//! whole-batch lockstep vs the continuous-batching engine on a bursty
//! phase-shift trace, through the REAL [`CascadeServer`] routing path —
//! plus two engine-feature sections: prefix sharing on a prefix-heavy
//! trace and chunked prefill on a long-prompt mix.
//!
//! Both headline modes serve the identical trace with backends whose
//! costs come from the same [`ReplicaModel`] the scheduler optimizes
//! against:
//!
//! * **lockstep** — a worker's `generate` sleeps the whole-request
//!   cost `prefill + tokens × decode_iteration(1)`: serial execution
//!   cannot amortize the per-iteration weight read across batchmates;
//! * **continuous** — a native [`StepBackend`] charges
//!   `prefill(chunk)` per prefill chunk and `decode_iteration(b)` per
//!   iteration at the LIVE batch size `b`, so batching amortization is
//!   exactly what the cost model says it is. Prompt tokens served from
//!   shared prefix pages are never prefilled at all.
//!
//! The **prefix** section serves a trace where every request carries a
//! shared system prompt twice — prefix trie off vs on — and reports
//! the peak page occupancy and backend-prefilled token reduction
//! (escalations re-serve their prompt at tier 1, so the deeper tier
//! shares across escalated requests too). The **chunked** section
//! serves a short/long prompt mix twice — whole-prompt admission vs a
//! chunk budget — and reports the p95 TTFT reduction from removing
//! prefill head-of-line blocking. The **spec** section serves an
//! escalate-everything trace twice — tier-1 cross-tier speculation off
//! vs on — and gates that agreement-heavy drafts cut deep-tier
//! iterations and p95 while both arms emit byte-identical outputs
//! (the losslessness contract, measured end to end).
//!
//! Time is compressed by `time_scale` (arrivals and sleeps divided,
//! latencies multiplied back for reporting) and decode is represented
//! at `token_scale` tokens per engine step so a run stays in CI
//! budgets. Per-request decode budgets come from the trace's own
//! output lengths ([`TraceEntry::max_new`]), so both modes reproduce
//! the trace's length mixture instead of a constant depth. Arrival
//! rates are derived from the model's own capacity terms. The report
//! (`BENCH_serving.json`) is the perf-trajectory artifact CI gates on
//! against `BENCH_baseline.json`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::ClusterSpec;
use crate::coordinator::server::{
    CascadeServer, ExecMode, ResponseJudger, ServeTelemetry, ServerConfig, ServerStats,
    TierBackend, TierEngineStats, TierQueueStats, TraceEntry,
};
use crate::judge::Judger;
use crate::metrics::LatencySummary;
use crate::models::{llama_cascade, ModelSpec};
use crate::perf::{ReplicaModel, DEFAULT_PREFILL_CHUNK};
use crate::router::PolicySpec;
use crate::sched::plan::{DisaggSpec, SpecSpec};
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::{estimate_stats, generate_phased, paper_trace, PhasedTraceSpec, Request};

use super::core::{EngineConfig, StepBackend, VerifyOutcome};
use super::kv::SeqId;
use super::scheduler::{PreemptionConfig, PreemptionMode};
use super::spec::draft_agrees;

/// Benchmark knobs; [`BenchConfig::full`] is what `cascadia bench`
/// runs, [`BenchConfig::smoke`] the CI-sized variant.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub seed: u64,
    /// Wall-clock compression: arrivals/sleeps divided, latencies
    /// multiplied back for reporting.
    pub time_scale: f64,
    /// Tokens represented per engine decode step.
    pub token_scale: usize,
    /// MEAN engine decode steps per request (per-request budgets scale
    /// around this with the trace's output-length mixture).
    pub decode_steps: usize,
    pub calm_requests: usize,
    pub burst_requests: usize,
    /// Squared coefficient of variation of the burst phase arrivals.
    pub burstiness: f64,
    /// Tier-0 acceptance bar.
    pub threshold: f64,
    pub page_tokens: usize,
    /// Prefill chunk budget of the continuous engine (headline +
    /// chunked section's "chunked" arm).
    pub prefill_chunk: usize,
    /// Prefix section: requests served, shared system-prompt tokens,
    /// and unique tail tokens per request.
    pub prefix_requests: usize,
    pub prefix_tokens: usize,
    pub prefix_tail_tokens: usize,
    /// Chunked section: short requests, long requests, and their
    /// prompt lengths.
    pub mix_short_requests: usize,
    pub mix_long_requests: usize,
    pub mix_short_tokens: usize,
    pub mix_long_tokens: usize,
    /// Swap section: long-context requests served through a pool sized
    /// to force eviction waves, and their decode depth (token-granular
    /// like the chunked section).
    pub swap_requests: usize,
    pub swap_prompt_tokens: usize,
    pub swap_decode_steps: usize,
    /// Disagg section: long-prompt requests served unified vs through
    /// a prefill/decode split of the same replica count, and their
    /// decode depth (token-granular like the chunked section).
    pub disagg_requests: usize,
    pub disagg_prompt_tokens: usize,
    pub disagg_decode_steps: usize,
    /// Speculation section: escalation-heavy requests served with
    /// tier-1 cross-tier speculation off vs on, their decode depth
    /// (token-granular like the chunked section), and the draft depth
    /// of the on arm.
    pub spec_requests: usize,
    pub spec_decode_steps: usize,
    pub spec_draft_k: usize,
}

impl BenchConfig {
    pub fn full() -> BenchConfig {
        BenchConfig {
            seed: 17,
            time_scale: 60.0,
            token_scale: 32,
            decode_steps: 8,
            calm_requests: 120,
            burst_requests: 200,
            burstiness: 4.0,
            threshold: 60.0,
            page_tokens: 16,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            prefix_requests: 160,
            prefix_tokens: 192,
            prefix_tail_tokens: 64,
            mix_short_requests: 120,
            mix_long_requests: 4,
            mix_short_tokens: 96,
            mix_long_tokens: 2048,
            swap_requests: 16,
            swap_prompt_tokens: 1040,
            swap_decode_steps: 64,
            disagg_requests: 40,
            disagg_prompt_tokens: 1024,
            disagg_decode_steps: 32,
            spec_requests: 24,
            spec_decode_steps: 48,
            spec_draft_k: 4,
        }
    }

    /// Tiny-trace smoke variant for CI: same shape, heavier
    /// compression.
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            calm_requests: 30,
            burst_requests: 60,
            time_scale: 240.0,
            token_scale: 48,
            decode_steps: 6,
            prefix_requests: 60,
            mix_short_requests: 48,
            mix_long_requests: 2,
            swap_requests: 10,
            disagg_requests: 24,
            spec_requests: 10,
            spec_decode_steps: 32,
            ..BenchConfig::full()
        }
    }

    /// Scale the prefix-heavy section up (the nightly `--prefix-heavy`
    /// trace): more requests, longer shared prefix.
    pub fn prefix_heavy(mut self) -> BenchConfig {
        self.prefix_requests *= 2;
        self.prefix_tokens *= 2;
        self
    }
}

/// One mode's results, in uncompressed time.
#[derive(Debug, Clone)]
pub struct ModeReport {
    pub label: String,
    pub served: usize,
    pub latency: LatencySummary,
    pub p95_ttft_s: f64,
    pub throughput_rps: f64,
    pub makespan_s: f64,
    pub per_tier_processed: Vec<usize>,
    pub queue: Vec<TierQueueStats>,
    pub engine: Vec<TierEngineStats>,
}

/// Prefix-sharing section: the same prefix-heavy trace with the trie
/// off vs on.
#[derive(Debug, Clone)]
pub struct PrefixReport {
    pub requests: usize,
    pub shared_prefix_tokens: usize,
    /// Sum over tiers of the peak page occupancy, trie off / on.
    pub baseline_peak_pages: usize,
    pub shared_peak_pages: usize,
    /// Prompt tokens the backends actually prefilled, trie off / on
    /// (escalation re-prefill cost included).
    pub baseline_prefill_tokens: usize,
    pub shared_prefill_tokens: usize,
    /// Tokens served from shared pages in the trie-on run.
    pub prefix_hit_tokens: usize,
    pub cow_copies: usize,
    /// Sharing cut BOTH peak occupancy and prefilled tokens.
    pub win: bool,
}

/// Swap-preemption section: a long-context preemption-heavy trace
/// served recompute-only vs swap-enabled through a pool sized so
/// eviction waves are structural (co-running contexts outgrow it
/// before any completes).
#[derive(Debug, Clone)]
pub struct SwapReport {
    pub requests: usize,
    pub prompt_tokens: usize,
    /// Device pool of the run (pages) — deliberately tight.
    pub pool_pages: usize,
    /// p95 end-to-end latency, uncompressed seconds.
    pub recompute_p95_s: f64,
    pub swap_p95_s: f64,
    /// recompute / swap (>1 = swap wins).
    pub p95_speedup: f64,
    /// Prompt tokens the backends prefilled in each run: recompute
    /// re-prefills every victim from token 0, the checkpointed swap
    /// run prefills each prompt exactly once.
    pub recompute_prefill_tokens: usize,
    pub swap_prefill_tokens: usize,
    /// Recompute-preemptions observed in the recompute-only run.
    pub preemptions: usize,
    /// Swap traffic observed in the swap-enabled run.
    pub swap_outs: usize,
    pub swap_ins: usize,
    pub swap_bytes: usize,
    /// Swap beat recompute on p95 AND checkpointed resume strictly
    /// reduced re-prefilled tokens.
    pub win: bool,
}

/// Chunked-prefill section: the same short/long mix with whole-prompt
/// admission vs the chunk budget.
#[derive(Debug, Clone)]
pub struct ChunkedReport {
    pub requests: usize,
    pub long_prompt_tokens: usize,
    pub prefill_chunk: usize,
    /// p95 submission-to-first-token, uncompressed seconds.
    pub whole_p95_ttft_s: f64,
    pub chunked_p95_ttft_s: f64,
    /// whole / chunked (>1 = chunking wins).
    pub ttft_speedup: f64,
    pub win: bool,
}

/// Disaggregation section: the same long-prompt decode-heavy trace
/// served by 2 unified tier-0 replicas vs a 1-prefill + 1-decode
/// split of the SAME replica count. Unified workers interleave new
/// prompts' prefill chunks with their residents' decode iterations,
/// so every chunk of a fresh prompt waits behind a full decode batch;
/// the split's prefill worker hands each sequence to the decode
/// worker right after its first token (charging the interconnect via
/// [`crate::perf::ReplicaModel::page_migrate_seconds`]), keeping its
/// own iterations prefill-pure. The section gates that the split cuts
/// p95 TTFT at equal request completion — the paper's case for
/// treating the split as a deployment dimension the scheduler owns.
#[derive(Debug, Clone)]
pub struct DisaggReport {
    pub requests: usize,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
    /// p95 submission-to-first-token, uncompressed seconds.
    pub unified_p95_ttft_s: f64,
    pub disagg_p95_ttft_s: f64,
    /// unified / disagg (>1 = the split wins).
    pub ttft_p95_speedup: f64,
    /// Handoffs observed decode-side in the split run (one per
    /// migrated sequence) and the private KV pages they moved.
    pub migrations: usize,
    pub migrate_pages: usize,
    /// Both arms served every request, the split actually migrated,
    /// and it beat unified on p95 TTFT.
    pub win: bool,
}

/// Speculation section: the same escalation-heavy trace served with
/// tier-1 cross-tier speculation off vs on. The on arm drafts
/// `draft_k` tokens per speculative step with a colocated cheap model
/// (its per-token cost is the shallow model at the deep tier's
/// parallelism) and verifies them in ONE deep-model iteration, on an
/// agreement-heavy stream — the regime the paper's cascade creates,
/// where the shallow tier already answered and mostly agrees. The
/// section gates the losslessness contract end to end: both arms must
/// emit byte-identical token streams per request while the on arm
/// strictly cuts deep-tier iterations and p95.
#[derive(Debug, Clone)]
pub struct SpecReport {
    pub requests: usize,
    /// Draft depth of the on arm's tier-1 pair.
    pub draft_k: usize,
    /// p95 end-to-end latency, uncompressed seconds, off / on.
    pub off_p95_s: f64,
    pub spec_p95_s: f64,
    /// off / spec (>1 = speculation wins).
    pub p95_speedup: f64,
    /// Deep-tier (tier 1) engine iterations, off / on — every accepted
    /// draft token is a deep iteration the verify model never ran.
    pub off_deep_iterations: usize,
    pub spec_deep_iterations: usize,
    /// Draft tokens the verifier accepted / rejected in the on arm.
    pub accepted_tokens: usize,
    pub rejected_tokens: usize,
    /// Per-request (id, accepting tier, output) triples are identical
    /// across the arms — the losslessness contract, measured.
    pub outputs_match: bool,
    /// Both arms served every request, outputs matched, drafts were
    /// accepted, and speculation strictly cut deep iterations AND p95.
    pub win: bool,
}

/// Tracing-overhead section: the headline trace re-served with the
/// span recorder + metrics registry detached vs attached. Recording
/// must be effectively free: the gate allows a 3% relative p95
/// regression plus 10 ms of *compressed* wall-clock slack
/// (multiplied back to uncompressed seconds by the run's time scale,
/// because time compression amplifies OS scheduling jitter by the
/// same factor).
#[derive(Debug, Clone)]
pub struct TracingReport {
    pub requests: usize,
    /// p95 end-to-end latency, uncompressed seconds.
    pub p95_off_s: f64,
    pub p95_on_s: f64,
    /// (on - off) / off.
    pub overhead_frac: f64,
    pub events_recorded: usize,
    pub dropped_events: usize,
    /// Tracing-on stayed inside the overhead budget, recorded at
    /// least one event per request, and the ring buffers dropped
    /// nothing.
    pub win: bool,
}

/// Profile-aggregation section: the tracing-on run's event stream
/// folded through [`crate::obs::ProfileAggregator`]. Gates that the
/// fold stays inside the tracing overhead budget (fold wall-clock
/// ≤ 3% of the traced run's wall-clock, with a 10 ms absolute floor
/// against OS jitter) and that the per-request waterfalls reconstruct
/// measured e2e latency (p95 attribution error ≤ 5%).
#[derive(Debug, Clone)]
pub struct ProfileSectionReport {
    /// Requests folded to a completed waterfall.
    pub requests: usize,
    /// Waterfalls whose span was opened by an `admitted` event (the
    /// attribution-error population).
    pub matched: usize,
    pub events_folded: u64,
    /// Wall-clock seconds spent folding (the fold runs at wall speed;
    /// no time scale applies).
    pub fold_wall_s: f64,
    /// Wall-clock seconds of the traced serving run it folds.
    pub run_wall_s: f64,
    /// fold_wall_s / run_wall_s.
    pub fold_frac: f64,
    /// p95 over requests of |waterfall phase sum − measured e2e|.
    pub p95_err_s: f64,
    pub p95_err_frac: f64,
    pub win: bool,
}

/// The full benchmark written to `BENCH_serving.json`.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub calm_rate: f64,
    pub burst_rate: f64,
    pub n_requests: usize,
    pub burstiness: f64,
    pub lockstep: ModeReport,
    pub continuous: ModeReport,
    /// lockstep p95 / continuous p95 (>1 = engine wins).
    pub p95_speedup: f64,
    /// continuous throughput / lockstep throughput (>1 = engine wins).
    pub throughput_gain: f64,
    /// Page occupancy stayed within the pool budget in every iteration
    /// (and no forced expansions fired) across ALL continuous runs.
    pub occupancy_ok: bool,
    /// Continuous beat lockstep on BOTH p95 and throughput.
    pub win: bool,
    pub prefix: PrefixReport,
    pub chunked: ChunkedReport,
    pub swap: SwapReport,
    pub disagg: DisaggReport,
    pub spec: SpecReport,
    pub tracing: TracingReport,
    pub profile: ProfileSectionReport,
}

impl BenchReport {
    /// Every gate the bench enforces: headline win, page budgets,
    /// prefix-sharing win, chunked-TTFT win, swap-preemption win,
    /// disaggregation win, speculation win, tracing-overhead win,
    /// profile-aggregation win.
    pub fn all_green(&self) -> bool {
        self.win
            && self.occupancy_ok
            && self.prefix.win
            && self.chunked.win
            && self.swap.win
            && self.disagg.win
            && self.spec.win
            && self.tracing.win
            && self.profile.win
    }

    pub fn to_json(&self) -> Json {
        let mode = |m: &ModeReport| {
            Json::obj(vec![
                ("served", Json::num(m.served as f64)),
                ("p50_s", Json::num(m.latency.p50)),
                ("p95_s", Json::num(m.latency.p95)),
                ("p99_s", Json::num(m.latency.p99)),
                ("mean_s", Json::num(m.latency.mean)),
                ("p95_ttft_s", Json::num(m.p95_ttft_s)),
                ("throughput_rps", Json::num(m.throughput_rps)),
                ("makespan_s", Json::num(m.makespan_s)),
                (
                    "per_tier_processed",
                    Json::arr(
                        m.per_tier_processed.iter().map(|&n| Json::num(n as f64)).collect(),
                    ),
                ),
                (
                    "queue",
                    Json::arr(
                        m.queue
                            .iter()
                            .map(|q| {
                                Json::obj(vec![
                                    ("peak_depth", Json::num(q.peak_depth as f64)),
                                    ("admitted", Json::num(q.admitted as f64)),
                                    ("mean_wait_s", Json::num(q.mean_wait_s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "engine",
                    Json::arr(
                        m.engine
                            .iter()
                            .map(|e| {
                                Json::obj(vec![
                                    ("pool_pages", Json::num(e.pool_pages as f64)),
                                    ("peak_pool_pages", Json::num(e.peak_pool_pages as f64)),
                                    ("peak_pages", Json::num(e.peak_pages as f64)),
                                    ("preemptions", Json::num(e.preemptions as f64)),
                                    ("iterations", Json::num(e.iterations as f64)),
                                    (
                                        "forced_expansions",
                                        Json::num(e.forced_expansions as f64),
                                    ),
                                    (
                                        "prefix_hit_tokens",
                                        Json::num(e.prefix_hit_tokens as f64),
                                    ),
                                    ("shared_claims", Json::num(e.shared_claims as f64)),
                                    ("cow_copies", Json::num(e.cow_copies as f64)),
                                    ("migrations", Json::num(e.migrations as f64)),
                                    ("migrate_pages", Json::num(e.migrate_pages as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        Json::obj(vec![
            (
                "trace",
                Json::obj(vec![
                    ("n_requests", Json::num(self.n_requests as f64)),
                    ("calm_rate_rps", Json::num(self.calm_rate)),
                    ("burst_rate_rps", Json::num(self.burst_rate)),
                    ("burstiness", Json::num(self.burstiness)),
                ]),
            ),
            ("lockstep", mode(&self.lockstep)),
            ("continuous", mode(&self.continuous)),
            ("p95_speedup", Json::num(self.p95_speedup)),
            ("throughput_gain", Json::num(self.throughput_gain)),
            ("occupancy_ok", Json::Bool(self.occupancy_ok)),
            ("win", Json::Bool(self.win)),
            (
                "prefix",
                Json::obj(vec![
                    ("requests", Json::num(self.prefix.requests as f64)),
                    (
                        "shared_prefix_tokens",
                        Json::num(self.prefix.shared_prefix_tokens as f64),
                    ),
                    (
                        "baseline_peak_pages",
                        Json::num(self.prefix.baseline_peak_pages as f64),
                    ),
                    ("shared_peak_pages", Json::num(self.prefix.shared_peak_pages as f64)),
                    (
                        "baseline_prefill_tokens",
                        Json::num(self.prefix.baseline_prefill_tokens as f64),
                    ),
                    (
                        "shared_prefill_tokens",
                        Json::num(self.prefix.shared_prefill_tokens as f64),
                    ),
                    ("prefix_hit_tokens", Json::num(self.prefix.prefix_hit_tokens as f64)),
                    ("cow_copies", Json::num(self.prefix.cow_copies as f64)),
                    ("win", Json::Bool(self.prefix.win)),
                ]),
            ),
            (
                "chunked",
                Json::obj(vec![
                    ("requests", Json::num(self.chunked.requests as f64)),
                    (
                        "long_prompt_tokens",
                        Json::num(self.chunked.long_prompt_tokens as f64),
                    ),
                    ("prefill_chunk", Json::num(self.chunked.prefill_chunk as f64)),
                    ("whole_p95_ttft_s", Json::num(self.chunked.whole_p95_ttft_s)),
                    ("chunked_p95_ttft_s", Json::num(self.chunked.chunked_p95_ttft_s)),
                    ("ttft_speedup", Json::num(self.chunked.ttft_speedup)),
                    ("win", Json::Bool(self.chunked.win)),
                ]),
            ),
            (
                "swap",
                Json::obj(vec![
                    ("requests", Json::num(self.swap.requests as f64)),
                    ("prompt_tokens", Json::num(self.swap.prompt_tokens as f64)),
                    ("pool_pages", Json::num(self.swap.pool_pages as f64)),
                    ("recompute_p95_s", Json::num(self.swap.recompute_p95_s)),
                    ("swap_p95_s", Json::num(self.swap.swap_p95_s)),
                    ("p95_speedup", Json::num(self.swap.p95_speedup)),
                    (
                        "recompute_prefill_tokens",
                        Json::num(self.swap.recompute_prefill_tokens as f64),
                    ),
                    (
                        "swap_prefill_tokens",
                        Json::num(self.swap.swap_prefill_tokens as f64),
                    ),
                    ("preemptions", Json::num(self.swap.preemptions as f64)),
                    ("swap_outs", Json::num(self.swap.swap_outs as f64)),
                    ("swap_ins", Json::num(self.swap.swap_ins as f64)),
                    ("swap_bytes", Json::num(self.swap.swap_bytes as f64)),
                    ("win", Json::Bool(self.swap.win)),
                ]),
            ),
            (
                "disagg",
                Json::obj(vec![
                    ("requests", Json::num(self.disagg.requests as f64)),
                    ("prompt_tokens", Json::num(self.disagg.prompt_tokens as f64)),
                    ("decode_steps", Json::num(self.disagg.decode_steps as f64)),
                    ("unified_p95_ttft_s", Json::num(self.disagg.unified_p95_ttft_s)),
                    ("disagg_p95_ttft_s", Json::num(self.disagg.disagg_p95_ttft_s)),
                    ("ttft_p95_speedup", Json::num(self.disagg.ttft_p95_speedup)),
                    ("migrations", Json::num(self.disagg.migrations as f64)),
                    ("migrate_pages", Json::num(self.disagg.migrate_pages as f64)),
                    ("win", Json::Bool(self.disagg.win)),
                ]),
            ),
            (
                "spec",
                Json::obj(vec![
                    ("requests", Json::num(self.spec.requests as f64)),
                    ("draft_k", Json::num(self.spec.draft_k as f64)),
                    ("off_p95_s", Json::num(self.spec.off_p95_s)),
                    ("spec_p95_s", Json::num(self.spec.spec_p95_s)),
                    ("p95_speedup", Json::num(self.spec.p95_speedup)),
                    (
                        "off_deep_iterations",
                        Json::num(self.spec.off_deep_iterations as f64),
                    ),
                    (
                        "spec_deep_iterations",
                        Json::num(self.spec.spec_deep_iterations as f64),
                    ),
                    ("accepted_tokens", Json::num(self.spec.accepted_tokens as f64)),
                    ("rejected_tokens", Json::num(self.spec.rejected_tokens as f64)),
                    ("outputs_match", Json::Bool(self.spec.outputs_match)),
                    ("win", Json::Bool(self.spec.win)),
                ]),
            ),
            (
                "tracing",
                Json::obj(vec![
                    ("requests", Json::num(self.tracing.requests as f64)),
                    ("p95_off_s", Json::num(self.tracing.p95_off_s)),
                    ("p95_on_s", Json::num(self.tracing.p95_on_s)),
                    ("overhead_frac", Json::num(self.tracing.overhead_frac)),
                    (
                        "events_recorded",
                        Json::num(self.tracing.events_recorded as f64),
                    ),
                    (
                        "dropped_events",
                        Json::num(self.tracing.dropped_events as f64),
                    ),
                    ("overhead_ok", Json::Bool(self.tracing.win)),
                    ("win", Json::Bool(self.tracing.win)),
                ]),
            ),
            (
                "profile",
                Json::obj(vec![
                    ("requests", Json::num(self.profile.requests as f64)),
                    ("matched", Json::num(self.profile.matched as f64)),
                    ("events_folded", Json::num(self.profile.events_folded as f64)),
                    ("fold_wall_s", Json::num(self.profile.fold_wall_s)),
                    ("run_wall_s", Json::num(self.profile.run_wall_s)),
                    ("fold_frac", Json::num(self.profile.fold_frac)),
                    ("p95_err_s", Json::num(self.profile.p95_err_s)),
                    ("p95_err_frac", Json::num(self.profile.p95_err_frac)),
                    ("fold_ok", Json::Bool(self.profile.win)),
                    ("win", Json::Bool(self.profile.win)),
                ]),
            ),
        ])
    }
}

/// Sleeps simulated seconds, batching sub-millisecond debts so OS
/// timer granularity does not swamp compressed iteration costs.
struct PacedSleeper {
    time_scale: f64,
    debt: f64,
}

impl PacedSleeper {
    fn pay(&mut self, sim_secs: f64) {
        self.debt += sim_secs / self.time_scale;
        if self.debt >= 1e-3 {
            std::thread::sleep(Duration::from_secs_f64(self.debt.min(5.0)));
            self.debt = 0.0;
        }
    }
}

/// Whole-request calibrated backend (the lockstep discipline): serial
/// execution pays the full unamortized decode cost per request, at the
/// request's OWN decode budget.
struct LockstepCalibrated {
    tier: usize,
    rm: ReplicaModel,
    token_scale: f64,
    sleeper: PacedSleeper,
}

impl TierBackend for LockstepCalibrated {
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let secs = self.rm.prefill_latency(prompt.len() as f64)
            + (max_new as f64 * self.token_scale) * self.rm.decode_iteration(1);
        self.sleeper.pay(secs);
        Ok(vec![self.tier as i32])
    }
}

/// Step-calibrated backend (the continuous engine): decode cost is
/// `decode_iteration(b)` at the LIVE batch size, prefill cost accrues
/// per chunk — and prefix-claimed tokens never reach this backend at
/// all, so their prefill cost is genuinely saved. `prefilled_tokens`
/// counts the prompt tokens actually processed (the re-prefill cost
/// the prefix section compares).
/// Speculation terms of a spec-enabled [`ContinuousCalibrated`]: the
/// colocated draft model's per-token decode cost and the agreement
/// modulus fed to [`draft_agrees`] (0 = every draft token agrees).
struct CalibratedSpec {
    draft_s_per_token: f64,
    agree_mod: u64,
    /// Verified tokens emitted so far per live sequence — the position
    /// key that keeps the draft agreement stream deterministic across
    /// decode/spec interleavings (cleared on release).
    emitted: BTreeMap<SeqId, usize>,
}

struct ContinuousCalibrated {
    tier: usize,
    rm: ReplicaModel,
    token_scale: f64,
    sleeper: PacedSleeper,
    prefilled_tokens: Arc<AtomicUsize>,
    /// Seconds per KV page moved across PCIe (the swap hook's rate).
    swap_s_per_page: f64,
    /// Seconds per KV page moved across the prefill→decode
    /// interconnect (the migrate hook's rate).
    migrate_s_per_page: f64,
    /// `Some` enables the native draft/verify hooks (the spec
    /// section's on arm); `None` keeps every other section on the
    /// plain decode path.
    spec: Option<CalibratedSpec>,
}

impl StepBackend for ContinuousCalibrated {
    fn prefill_chunk(&mut self, seq: SeqId, chunk: &[i32], last: bool) -> Result<Option<i32>> {
        self.prefilled_tokens.fetch_add(chunk.len(), Ordering::SeqCst);
        let secs = self.rm.prefill_latency(chunk.len() as f64);
        self.sleeper.pay(secs);
        if last {
            if let Some(sp) = &mut self.spec {
                sp.emitted.insert(seq, 1);
            }
        }
        Ok(last.then_some(self.tier as i32))
    }

    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        let secs = self.rm.decode_iteration(seqs.len()) * self.token_scale;
        self.sleeper.pay(secs);
        if let Some(sp) = &mut self.spec {
            for &s in seqs {
                *sp.emitted.entry(s).or_insert(0) += 1;
            }
        }
        Ok(vec![self.tier as i32; seqs.len()])
    }

    fn release(&mut self, seq: SeqId) {
        if let Some(sp) = &mut self.spec {
            sp.emitted.remove(&seq);
        }
    }

    fn draft(&mut self, seq: SeqId, k: usize) -> Result<Option<Vec<i32>>> {
        let Some(sp) = &mut self.spec else { return Ok(None) };
        let base = sp.emitted.get(&seq).copied().unwrap_or(0);
        let me = self.tier as i32;
        // A disagreeing draft token is anything the verify model would
        // not emit; it is never part of the output stream (the engine
        // emits only the accepted prefix plus the verifier's token).
        let toks: Vec<i32> = (0..k)
            .map(|i| if draft_agrees(seq, base + i, sp.agree_mod) { me } else { me + 101 })
            .collect();
        let secs = k as f64 * sp.draft_s_per_token * self.token_scale;
        self.sleeper.pay(secs);
        Ok(Some(toks))
    }

    fn verify(&mut self, seq: SeqId, draft: &[i32]) -> Result<Option<VerifyOutcome>> {
        let Some(sp) = &mut self.spec else { return Ok(None) };
        // ONE deep-model iteration scores the whole draft — the step
        // speculation's economics buy (conservatively priced at batch
        // 1: the section paces the deep tier to serial occupancy).
        let secs = self.rm.decode_iteration(1) * self.token_scale;
        self.sleeper.pay(secs);
        let me = self.tier as i32;
        let accepted = draft.iter().take_while(|&&t| t == me).count();
        *sp.emitted.entry(seq).or_insert(0) += accepted + 1;
        Ok(Some(VerifyOutcome { accepted, next: me }))
    }

    fn swap(&mut self, _seq: SeqId, pages: usize, _to_host: bool) {
        // A swap is not free: the PCIe move charges real (compressed)
        // time, so the recompute-vs-swap comparison the bench reports
        // is a genuine cost tradeoff, not an accounting trick.
        self.sleeper.pay(pages as f64 * self.swap_s_per_page);
    }

    fn migrate(&mut self, _seq: SeqId, pages: usize) {
        // A prefill→decode handoff pays the one-way interconnect move
        // of its private pages (the decode engine fires this hook on
        // arrival), so the unified-vs-split comparison prices the
        // transfer the same way the scheduler's estimator does.
        self.sleeper.pay(pages as f64 * self.migrate_s_per_page);
    }
}

impl TierBackend for ContinuousCalibrated {
    fn generate(&mut self, prompt: &[i32], _max_new: usize) -> Result<Vec<i32>> {
        // Fallback (unused on the engine path): whole-request cost.
        let secs = self.rm.prefill_latency(prompt.len() as f64)
            + self.token_scale * self.rm.decode_iteration(1);
        self.sleeper.pay(secs);
        Ok(vec![self.tier as i32])
    }

    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

/// Scores a benchmark response with the offline judger. The request id
/// rides in the prompt's LAST token (so shared prompt *prefixes* stay
/// byte-identical across requests and the prefix trie sees them);
/// output\[0\] carries the serving tier.
struct BenchJudger {
    requests: Vec<Request>,
    models: Vec<ModelSpec>,
    judger: Judger,
}

impl ResponseJudger for BenchJudger {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64 {
        let id = prompt.last().copied().unwrap_or(0).max(0) as usize;
        let tier = (output.first().copied().unwrap_or(0).max(0) as usize)
            .min(self.models.len() - 1);
        match self.requests.get(id) {
            Some(req) => self.judger.score(&self.models[tier], req, tier),
            None => 0.0,
        }
    }
}

fn mode_report(label: &str, stats: &ServerStats, time_scale: f64) -> ModeReport {
    let lat: Vec<f64> = stats
        .completions
        .iter()
        .map(|c| c.e2e_latency.as_secs_f64() * time_scale)
        .collect();
    let makespan = stats.wall_clock.as_secs_f64() * time_scale;
    ModeReport {
        label: label.to_string(),
        served: stats.completions.len(),
        latency: LatencySummary::of(&lat),
        p95_ttft_s: stats.p95_ttft() * time_scale,
        throughput_rps: stats.completions.len() as f64 / makespan.max(1e-9),
        makespan_s: makespan,
        per_tier_processed: stats.per_tier_processed.clone(),
        queue: stats
            .queue
            .iter()
            .map(|q| TierQueueStats { mean_wait_s: q.mean_wait_s * time_scale, ..*q })
            .collect(),
        engine: stats.engine.clone(),
    }
}

fn occupancy_ok(engine: &[TierEngineStats]) -> bool {
    engine
        .iter()
        .all(|e| e.peak_pages <= e.peak_pool_pages && e.forced_expansions == 0)
}

/// A deterministic filler token unique to (request, position): shared
/// prefixes are built separately, tails never collide across requests.
fn tail_token(id: usize, j: usize) -> i32 {
    ((id.wrapping_mul(1009) + j.wrapping_mul(31)) % 7919) as i32 + 1
}

/// Build a prompt of `prefix` shared tokens + `tail` unique tokens,
/// with the request id in the LAST slot (the judger's key).
fn prompt_with_prefix(id: usize, prefix_tokens: usize, tail_tokens: usize) -> Vec<i32> {
    let mut p = Vec::with_capacity(prefix_tokens + tail_tokens.max(1));
    p.extend((0..prefix_tokens).map(|j| (j % 977) as i32 + 13));
    p.extend((0..tail_tokens.saturating_sub(1)).map(|j| tail_token(id, j)));
    p.push(id as i32);
    p
}

/// The two replica cost models of the benchmark cascade (the 8B tier
/// on single GPUs, the 70B tier on a TP-8 server — the shapes the
/// paper's testbed serves them at).
fn bench_rms(cascade: &[ModelSpec], cluster: &ClusterSpec, avg_ctx: f64) -> Vec<ReplicaModel> {
    vec![
        ReplicaModel::new(&cascade[0], cluster, 1, 1, avg_ctx),
        ReplicaModel::new(&cascade[1], cluster, 8, 1, avg_ctx),
    ]
}

struct ContinuousRun {
    stats: ServerStats,
    prefilled_tokens: usize,
}

/// Serve `trace` on a 2-tier continuous server with the given engine
/// overrides, returning stats plus the backend-prefilled token count.
/// `pool_pages` overrides every tier's pool size (the swap section's
/// deliberately tight pools); `preemption` selects the eviction
/// discipline, with per-tier swap budget/cost terms derived from each
/// tier's own replica model; `disagg` optionally splits tiers into
/// prefill/decode role pools (empty = unified); `speculation` is the
/// server's per-tier draft configuration and `spec_backend` the
/// `(draft seconds per token, agreement modulus)` terms handed to
/// every backend's native draft/verify hooks (both empty/`None`
/// everywhere but the speculation section's on arm).
#[allow(clippy::too_many_arguments)]
fn run_continuous(
    trace: &[TraceEntry],
    judger: &BenchJudger,
    rms: &[ReplicaModel],
    replicas: Vec<usize>,
    max_batch: Vec<usize>,
    threshold: f64,
    max_new_default: usize,
    page_tokens: usize,
    prefill_chunk: usize,
    share_prefixes: bool,
    pool_pages: Option<usize>,
    preemption: PreemptionMode,
    disagg: Vec<Option<DisaggSpec>>,
    speculation: Vec<Option<SpecSpec>>,
    spec_backend: Option<(f64, u64)>,
    time_scale: f64,
    token_scale: f64,
    telemetry: Option<Arc<ServeTelemetry>>,
) -> Result<ContinuousRun> {
    let engines: Vec<EngineConfig> = rms
        .iter()
        .map(|rm| {
            let mut e = EngineConfig {
                prefill_chunk,
                share_prefixes,
                preemption: PreemptionConfig::from_replica(rm, page_tokens, preemption),
                ..EngineConfig::for_replica(rm, page_tokens)
            };
            if let Some(p) = pool_pages {
                e.pool_pages = p.max(1);
            }
            e
        })
        .collect();
    let mut server = CascadeServer::new(ServerConfig {
        replicas,
        max_batch,
        policy: PolicySpec::threshold(vec![threshold])?,
        max_new_tokens: max_new_default,
        exec: ExecMode::Continuous(engines),
        disagg,
        speculation,
    })?;
    server.set_telemetry(telemetry);
    let prefilled = Arc::new(AtomicUsize::new(0));
    let rms_owned = rms.to_vec();
    let prefilled_f = Arc::clone(&prefilled);
    let factory = move |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(ContinuousCalibrated {
            tier,
            rm: rms_owned[tier].clone(),
            token_scale,
            sleeper: PacedSleeper { time_scale, debt: 0.0 },
            prefilled_tokens: Arc::clone(&prefilled_f),
            swap_s_per_page: rms_owned[tier].page_swap_seconds(page_tokens),
            migrate_s_per_page: rms_owned[tier].page_migrate_seconds(page_tokens),
            spec: spec_backend.map(|(draft_s_per_token, agree_mod)| CalibratedSpec {
                draft_s_per_token,
                agree_mod,
                emitted: BTreeMap::new(),
            }),
        }))
    };
    let stats = server.serve_entries(trace, &factory, judger)?;
    Ok(ContinuousRun { stats, prefilled_tokens: prefilled.load(Ordering::SeqCst) })
}

/// Run the calibrated lockstep-vs-continuous serving benchmark plus
/// the prefix-sharing and chunked-prefill sections.
pub fn run_serving_bench(cfg: &BenchConfig) -> Result<BenchReport> {
    let cascade = llama_cascade();
    let cluster = ClusterSpec::paper_testbed();
    let replicas: Vec<usize> = vec![2, 1];
    let max_batch: Vec<usize> = vec![16, 8];
    let decode_tokens = (cfg.decode_steps * cfg.token_scale) as f64;

    // Probe trace for mean lengths (rates don't matter here).
    let probe = generate_phased(
        &PhasedTraceSpec {
            phases: vec![
                (paper_trace(3, 1.0), cfg.calm_requests.max(50)),
                (paper_trace(1, 1.0), cfg.burst_requests.max(50)),
            ],
        },
        cfg.seed,
    );
    let avg_in = estimate_stats(&probe.requests).avg_input;
    let avg_ctx = avg_in + decode_tokens;
    let rms = bench_rms(&cascade, &cluster, avg_ctx);

    // Capacity-derived rates: the burst is provisioned ABOVE lockstep
    // capacity but comfortably inside continuous capacity, on the
    // cascade's bottleneck tier (tier 1 sees ~half the traffic via
    // escalation on the hard phase).
    let esc = 0.5;
    let lock_cap = |t: usize| {
        replicas[t] as f64
            / (rms[t].prefill_latency(avg_in) + decode_tokens * rms[t].decode_iteration(1))
    };
    let cont_cap = |t: usize| {
        let b = (max_batch[t] / replicas[t]).clamp(1, rms[t].max_batch.max(1));
        replicas[t] as f64 * b as f64
            / (decode_tokens * rms[t].decode_iteration(b)
                + b as f64 * rms[t].prefill_latency(avg_in))
    };
    let bound_lock = lock_cap(0).min(lock_cap(1) / esc);
    let bound_cont = cont_cap(0).min(cont_cap(1) / esc);
    let burst_rate = (1.5 * bound_lock).min(0.7 * bound_cont).max(1.02 * bound_lock);
    let calm_rate = 0.4 * bound_lock;

    // The bursty phase-shift trace: calm/easy, then a bursty hard
    // phase (gamma renewal with SCV = burstiness).
    let mut burst_spec = paper_trace(1, burst_rate);
    burst_spec.burstiness = cfg.burstiness;
    let phased = generate_phased(
        &PhasedTraceSpec {
            phases: vec![
                (paper_trace(3, calm_rate), cfg.calm_requests),
                (burst_spec, cfg.burst_requests),
            ],
        },
        cfg.seed,
    );
    // Per-request decode budgets reproduce the trace's output-length
    // mixture, normalized so the mean stays at `decode_steps` (which
    // the rate calibration above assumed).
    let raw: Vec<f64> =
        phased.requests.iter().map(|r| r.output_tokens.max(1) as f64).collect();
    let raw_mean = stats::mean(&raw).max(1.0);
    let steps_of = |out: f64| -> usize {
        ((out / raw_mean * cfg.decode_steps as f64).round() as usize)
            .clamp(1, cfg.decode_steps * 4)
    };
    let trace: Vec<TraceEntry> = phased
        .requests
        .iter()
        .map(|r| {
            let len = (r.input_tokens as usize).clamp(2, 4096);
            let mut prompt: Vec<i32> =
                (0..len - 1).map(|j| tail_token(r.id as usize, j)).collect();
            prompt.push(r.id as i32);
            TraceEntry {
                at: r.arrival / cfg.time_scale,
                prompt,
                max_new: Some(steps_of(r.output_tokens.max(1) as f64)),
            }
        })
        .collect();

    let judger = BenchJudger {
        requests: phased.requests.clone(),
        models: cascade.clone(),
        judger: Judger::new(cfg.seed),
    };
    let policy = PolicySpec::threshold(vec![cfg.threshold])?;

    // --- Lockstep baseline ---
    let lock_server = CascadeServer::new(ServerConfig {
        replicas: replicas.clone(),
        max_batch: max_batch.clone(),
        policy: policy.clone(),
        max_new_tokens: cfg.decode_steps,
        exec: ExecMode::BatchLockstep,
        disagg: Vec::new(),
        speculation: Vec::new(),
    })?;
    let rms_lock = rms.clone();
    let (ts, tsc) = (cfg.time_scale, cfg.token_scale as f64);
    let lock_factory = move |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(LockstepCalibrated {
            tier,
            rm: rms_lock[tier].clone(),
            token_scale: tsc,
            sleeper: PacedSleeper { time_scale: ts, debt: 0.0 },
        }))
    };
    let lock_stats = lock_server
        .serve_entries(&trace, &lock_factory, &judger)
        .context("lockstep benchmark run")?;

    // --- Continuous engine (chunked prefill + prefix trie on) ---
    let engines: Vec<EngineConfig> = rms
        .iter()
        .map(|rm| EngineConfig {
            prefill_chunk: cfg.prefill_chunk,
            ..EngineConfig::for_replica(rm, cfg.page_tokens)
        })
        .collect();
    let cont_server = CascadeServer::new(ServerConfig {
        replicas: replicas.clone(),
        max_batch: max_batch.clone(),
        policy,
        max_new_tokens: cfg.decode_steps,
        exec: ExecMode::Continuous(engines),
        disagg: Vec::new(),
        speculation: Vec::new(),
    })?;
    let rms_cont = rms.clone();
    let cont_prefilled = Arc::new(AtomicUsize::new(0));
    let cont_prefilled_f = Arc::clone(&cont_prefilled);
    let cont_factory = move |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(ContinuousCalibrated {
            tier,
            rm: rms_cont[tier].clone(),
            token_scale: tsc,
            sleeper: PacedSleeper { time_scale: ts, debt: 0.0 },
            prefilled_tokens: Arc::clone(&cont_prefilled_f),
            swap_s_per_page: 0.0,
            migrate_s_per_page: 0.0,
        }))
    };
    let cont_stats = cont_server
        .serve_entries(&trace, &cont_factory, &judger)
        .context("continuous benchmark run")?;

    let lockstep = mode_report("lockstep", &lock_stats, cfg.time_scale);
    let continuous = mode_report("continuous", &cont_stats, cfg.time_scale);
    let mut all_occupancy_ok = occupancy_ok(&continuous.engine);
    let p95_speedup = lockstep.latency.p95 / continuous.latency.p95.max(1e-9);
    let throughput_gain = continuous.throughput_rps / lockstep.throughput_rps.max(1e-9);
    let win = p95_speedup > 1.0 && throughput_gain > 1.0;

    // --- Prefix section: trie off vs on, same prefix-heavy trace ---
    let prefix = {
        let n = cfg.prefix_requests.max(8);
        let reqs: Vec<Request> = {
            // Hard-ish synthetic complexities so a stable fraction
            // escalates and re-serves its prompt at tier 1.
            let mut spec = paper_trace(1, 1.0);
            spec.burstiness = 1.0;
            crate::workload::generate(&spec, n, cfg.seed.wrapping_add(3))
        };
        let avg_in_p = (cfg.prefix_tokens + cfg.prefix_tail_tokens) as f64;
        let rms_p = bench_rms(&cascade, &cluster, avg_in_p + decode_tokens);
        // Moderate overlap: ~4 co-resident requests per tier-0 worker.
        let service = rms_p[0].prefill_latency(avg_in_p)
            + cfg.decode_steps as f64 * cfg.token_scale as f64 * rms_p[0].decode_iteration(4)
                / 4.0;
        let rate = 4.0 * replicas[0] as f64 / service.max(1e-6);
        let ptrace: Vec<TraceEntry> = reqs
            .iter()
            .enumerate()
            .map(|(i, _)| TraceEntry {
                at: i as f64 / rate / cfg.time_scale,
                prompt: prompt_with_prefix(i, cfg.prefix_tokens, cfg.prefix_tail_tokens),
                max_new: Some(cfg.decode_steps),
            })
            .collect();
        let pjudger = BenchJudger {
            requests: reqs,
            models: cascade.clone(),
            judger: Judger::new(cfg.seed.wrapping_add(3)),
        };
        let base = run_continuous(
            &ptrace,
            &pjudger,
            &rms_p,
            replicas.clone(),
            max_batch.clone(),
            cfg.threshold,
            cfg.decode_steps,
            cfg.page_tokens,
            cfg.prefill_chunk,
            false,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            cfg.time_scale,
            cfg.token_scale as f64,
            None,
        )
        .context("prefix baseline run")?;
        let shared = run_continuous(
            &ptrace,
            &pjudger,
            &rms_p,
            replicas.clone(),
            max_batch.clone(),
            cfg.threshold,
            cfg.decode_steps,
            cfg.page_tokens,
            cfg.prefill_chunk,
            true,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            cfg.time_scale,
            cfg.token_scale as f64,
            None,
        )
        .context("prefix shared run")?;
        all_occupancy_ok = all_occupancy_ok
            && occupancy_ok(&base.stats.engine)
            && occupancy_ok(&shared.stats.engine);
        let peak = |s: &ServerStats| -> usize {
            s.engine.iter().map(|e| e.peak_pages).sum()
        };
        let hit: usize = shared.stats.engine.iter().map(|e| e.prefix_hit_tokens).sum();
        let cows: usize = shared.stats.engine.iter().map(|e| e.cow_copies).sum();
        let (bp, sp) = (peak(&base.stats), peak(&shared.stats));
        PrefixReport {
            requests: n,
            shared_prefix_tokens: cfg.prefix_tokens,
            baseline_peak_pages: bp,
            shared_peak_pages: sp,
            baseline_prefill_tokens: base.prefilled_tokens,
            shared_prefill_tokens: shared.prefilled_tokens,
            prefix_hit_tokens: hit,
            cow_copies: cows,
            win: sp < bp && shared.prefilled_tokens < base.prefilled_tokens,
        }
    };

    // --- Chunked section: whole vs chunked prefill, short/long mix.
    // Decode runs token-granular here (token_scale 1, more steps):
    // prefill must be commensurate with iteration time or head-of-line
    // blocking is invisible under the headline's coarse token_scale. ---
    let chunked = {
        let n_short = cfg.mix_short_requests.max(8);
        let n_long = cfg.mix_long_requests.max(1);
        let n = n_short + n_long;
        let steps_c = 24usize; // decode tokens per request, 1:1 scale
        let chunk = cfg.prefill_chunk.min(cfg.mix_long_tokens / 4).max(1);
        let reqs: Vec<Request> = {
            let mut spec = paper_trace(3, 1.0);
            spec.burstiness = 1.0;
            crate::workload::generate(&spec, n, cfg.seed.wrapping_add(5))
        };
        let rms_c = bench_rms(
            &cascade,
            &cluster,
            cfg.mix_short_tokens as f64 + steps_c as f64,
        );
        // ~60% of tier-0 continuous capacity: queues stay bounded, yet
        // several shorts land inside one long prompt's whole-prefill
        // window.
        let b = (max_batch[0] / replicas[0]).clamp(1, rms_c[0].max_batch.max(1));
        let cap = replicas[0] as f64 * b as f64
            / (steps_c as f64 * rms_c[0].decode_iteration(b)
                + b as f64 * rms_c[0].prefill_latency(cfg.mix_short_tokens as f64));
        let rate = 0.6 * cap;
        let every = (n_short / n_long).max(2);
        let ctrace: Vec<TraceEntry> = (0..n)
            .map(|i| {
                let long = i % every == 1 && i / every < n_long;
                let len = if long { cfg.mix_long_tokens } else { cfg.mix_short_tokens };
                let mut prompt: Vec<i32> =
                    (0..len - 1).map(|j| tail_token(i + 100_000, j)).collect();
                prompt.push(i as i32);
                TraceEntry {
                    at: i as f64 / rate / cfg.time_scale,
                    prompt,
                    max_new: Some(steps_c),
                }
            })
            .collect();
        let cjudger = BenchJudger {
            requests: reqs,
            models: cascade.clone(),
            judger: Judger::new(cfg.seed.wrapping_add(5)),
        };
        // Accept everything at tier 0 (threshold 0): the section
        // isolates prefill head-of-line blocking from routing.
        let whole = run_continuous(
            &ctrace,
            &cjudger,
            &rms_c,
            replicas.clone(),
            max_batch.clone(),
            0.0,
            steps_c,
            cfg.page_tokens,
            usize::MAX,
            false,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            cfg.time_scale,
            1.0,
            None,
        )
        .context("chunked-section whole-prefill run")?;
        let chunked_run = run_continuous(
            &ctrace,
            &cjudger,
            &rms_c,
            replicas.clone(),
            max_batch.clone(),
            0.0,
            steps_c,
            cfg.page_tokens,
            chunk,
            false,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            cfg.time_scale,
            1.0,
            None,
        )
        .context("chunked-section chunked run")?;
        all_occupancy_ok = all_occupancy_ok
            && occupancy_ok(&whole.stats.engine)
            && occupancy_ok(&chunked_run.stats.engine);
        let wttft = whole.stats.p95_ttft() * cfg.time_scale;
        let cttft = chunked_run.stats.p95_ttft() * cfg.time_scale;
        ChunkedReport {
            requests: n,
            long_prompt_tokens: cfg.mix_long_tokens,
            prefill_chunk: chunk,
            whole_p95_ttft_s: wttft,
            chunked_p95_ttft_s: cttft,
            ttft_speedup: wttft / cttft.max(1e-9),
            win: cttft < wttft,
        }
    };

    // --- Swap section: recompute-only vs swap-enabled preemption on a
    // long-context preemption-heavy trace. The pool holds two
    // admissions but not their decode growth, so eviction waves are
    // structural: recompute restarts the newest victim from token 0
    // (its prefill AND generated tokens are repaid through the
    // calibrated backend), swap parks it over PCIe and resumes from
    // the checkpoint. Decode runs token-granular like the chunked
    // section. ---
    let swap = {
        let n = cfg.swap_requests.max(6);
        let prompt_tokens = cfg.swap_prompt_tokens.max(2 * cfg.page_tokens);
        let steps_s = cfg.swap_decode_steps.max(2 * cfg.page_tokens);
        // Gentler compression than the headline: the section's win
        // margin is measured in re-prefill waste, and heavy time
        // compression amplifies OS scheduling jitter by the same
        // factor.
        let ts_s = (cfg.time_scale / 4.0).max(1.0);
        let rms_s = bench_rms(&cascade, &cluster, prompt_tokens as f64 + steps_s as f64);
        // Admission takes prompt+1 tokens of pages; two co-runners fit,
        // their growth does not.
        let admit_pages = (prompt_tokens + 1).div_ceil(cfg.page_tokens);
        let pool_pages = 2 * admit_pages + 1;
        let reqs: Vec<Request> = {
            let mut spec = paper_trace(3, 1.0);
            spec.burstiness = 1.0;
            crate::workload::generate(&spec, n, cfg.seed.wrapping_add(7))
        };
        let strace: Vec<TraceEntry> = (0..n)
            .map(|i| {
                let mut prompt: Vec<i32> =
                    (0..prompt_tokens - 1).map(|j| tail_token(i + 300_000, j)).collect();
                prompt.push(i as i32);
                // A burst: everything queues immediately, so the pool
                // pressure (not arrival pacing) drives the dynamics.
                TraceEntry { at: i as f64 * 1e-6, prompt, max_new: Some(steps_s) }
            })
            .collect();
        let sjudger = BenchJudger {
            requests: reqs,
            models: cascade.clone(),
            judger: Judger::new(cfg.seed.wrapping_add(7)),
        };
        // Accept everything at tier 0: the section isolates the
        // eviction discipline from routing.
        let recompute = run_continuous(
            &strace,
            &sjudger,
            &rms_s,
            replicas.clone(),
            vec![n.max(4), 4],
            0.0,
            steps_s,
            cfg.page_tokens,
            usize::MAX,
            false,
            Some(pool_pages),
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            ts_s,
            1.0,
            None,
        )
        .context("swap-section recompute run")?;
        let swapped = run_continuous(
            &strace,
            &sjudger,
            &rms_s,
            replicas.clone(),
            vec![n.max(4), 4],
            0.0,
            steps_s,
            cfg.page_tokens,
            usize::MAX,
            false,
            Some(pool_pages),
            PreemptionMode::Swap,
            Vec::new(),
            Vec::new(),
            None,
            ts_s,
            1.0,
            None,
        )
        .context("swap-section swap run")?;
        all_occupancy_ok = all_occupancy_ok
            && occupancy_ok(&recompute.stats.engine)
            && occupancy_ok(&swapped.stats.engine);
        let rec_p95 = recompute.stats.p95_latency() * ts_s;
        let swp_p95 = swapped.stats.p95_latency() * ts_s;
        let preemptions: usize = recompute.stats.engine.iter().map(|e| e.preemptions).sum();
        let swap_outs: usize = swapped.stats.engine.iter().map(|e| e.swap_outs).sum();
        let swap_ins: usize = swapped.stats.engine.iter().map(|e| e.swap_ins).sum();
        let swap_bytes: usize = swapped.stats.engine.iter().map(|e| e.swap_bytes).sum();
        SwapReport {
            requests: n,
            prompt_tokens,
            pool_pages,
            recompute_p95_s: rec_p95,
            swap_p95_s: swp_p95,
            p95_speedup: rec_p95 / swp_p95.max(1e-9),
            recompute_prefill_tokens: recompute.prefilled_tokens,
            swap_prefill_tokens: swapped.prefilled_tokens,
            preemptions,
            swap_outs,
            swap_ins,
            swap_bytes,
            win: swp_p95 <= rec_p95
                && swapped.prefilled_tokens < recompute.prefilled_tokens,
        }
    };

    // --- Disagg section: 2 unified tier-0 replicas vs a 1-prefill +
    // 1-decode split of the SAME replica count, on a long-prompt
    // decode-heavy trace. Decode runs token-granular (token_scale 1)
    // like the chunked section: every prefill chunk of a fresh prompt
    // on a unified worker rides an iteration that also pays
    // decode_iteration(b) for the worker's residents, so unified TTFT
    // carries a chunks × decode-batch interference term the split's
    // prefill-pure worker never pays (its sequences hand off to the
    // decode worker right after their first token). ---
    let disagg = {
        let n = cfg.disagg_requests.max(8);
        let prompt_tokens = cfg.disagg_prompt_tokens.max(4 * cfg.page_tokens);
        let steps_d = cfg.disagg_decode_steps.max(8);
        let chunk = (prompt_tokens / 8).max(cfg.page_tokens);
        // Gentler compression than the headline (same reasoning as the
        // swap section): the win margin is per-chunk interference.
        let ts_d = (cfg.time_scale / 4.0).max(1.0);
        let rms_d = bench_rms(&cascade, &cluster, prompt_tokens as f64 + steps_d as f64);
        // Pace arrivals at ~55% of the binding arm: the split's lone
        // prefill worker, its lone decode worker, and the unified pair
        // must ALL be stable, so the p95 TTFT delta measures
        // interference rather than saturation of either arm.
        let bd = max_batch[0].clamp(1, rms_d[0].max_batch.max(1));
        let prefill_cap = 1.0 / rms_d[0].prefill_latency(prompt_tokens as f64).max(1e-9);
        let decode_cap =
            bd as f64 / (steps_d as f64 * rms_d[0].decode_iteration(bd)).max(1e-9);
        let unified_cap = {
            let bu = (max_batch[0] / replicas[0]).clamp(1, rms_d[0].max_batch.max(1));
            replicas[0] as f64 * bu as f64
                / (steps_d as f64 * rms_d[0].decode_iteration(bu)
                    + bu as f64 * rms_d[0].prefill_latency(prompt_tokens as f64))
        };
        let rate = 0.55 * prefill_cap.min(decode_cap).min(unified_cap);
        let reqs: Vec<Request> = {
            let mut spec = paper_trace(3, 1.0);
            spec.burstiness = 1.0;
            crate::workload::generate(&spec, n, cfg.seed.wrapping_add(11))
        };
        let dtrace: Vec<TraceEntry> = (0..n)
            .map(|i| {
                let mut prompt: Vec<i32> =
                    (0..prompt_tokens - 1).map(|j| tail_token(i + 500_000, j)).collect();
                prompt.push(i as i32);
                TraceEntry { at: i as f64 / rate / ts_d, prompt, max_new: Some(steps_d) }
            })
            .collect();
        let djudger = BenchJudger {
            requests: reqs,
            models: cascade.clone(),
            judger: Judger::new(cfg.seed.wrapping_add(11)),
        };
        // Accept everything at tier 0: the section isolates the
        // prefill/decode split from routing.
        let unified = run_continuous(
            &dtrace,
            &djudger,
            &rms_d,
            replicas.clone(),
            max_batch.clone(),
            0.0,
            steps_d,
            cfg.page_tokens,
            chunk,
            false,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            ts_d,
            1.0,
            None,
        )
        .context("disagg-section unified run")?;
        let split = run_continuous(
            &dtrace,
            &djudger,
            &rms_d,
            replicas.clone(),
            max_batch.clone(),
            0.0,
            steps_d,
            cfg.page_tokens,
            chunk,
            false,
            None,
            PreemptionMode::Recompute,
            vec![Some(DisaggSpec { prefill_replicas: 1, decode_replicas: 1 }), None],
            Vec::new(),
            None,
            ts_d,
            1.0,
            None,
        )
        .context("disagg-section split run")?;
        all_occupancy_ok = all_occupancy_ok
            && occupancy_ok(&unified.stats.engine)
            && occupancy_ok(&split.stats.engine);
        let uttft = unified.stats.p95_ttft() * ts_d;
        let dttft = split.stats.p95_ttft() * ts_d;
        let migrations: usize = split.stats.engine.iter().map(|e| e.migrations).sum();
        let migrate_pages: usize =
            split.stats.engine.iter().map(|e| e.migrate_pages).sum();
        DisaggReport {
            requests: n,
            prompt_tokens,
            decode_steps: steps_d,
            unified_p95_ttft_s: uttft,
            disagg_p95_ttft_s: dttft,
            ttft_p95_speedup: uttft / dttft.max(1e-9),
            migrations,
            migrate_pages,
            win: unified.stats.completions.len() == n
                && split.stats.completions.len() == n
                && migrations > 0
                && dttft < uttft,
        }
    };

    // --- Speculation section: an escalate-everything trace (threshold
    // above the judger's score ceiling, so every request reaches the
    // deep tier) served twice, tier-1 cross-tier speculation off vs on. Decode runs
    // token-granular (token_scale 1) and arrivals pace the deep tier
    // to serial occupancy, so the on/off delta is the draft/verify
    // economics — k cheap draft tokens plus ONE deep iteration vs k+1
    // deep iterations — not batch amortization. The draft stream is
    // agreement-heavy (agree_mod 0: every draft token agrees), the
    // regime the cascade creates where the shallow tier already
    // answered. Outputs must stay byte-identical: every emitted token
    // is a verify-model token. ---
    let spec = {
        let n = cfg.spec_requests.max(6);
        let steps_p = cfg.spec_decode_steps.max(8);
        let k = cfg.spec_draft_k.max(1);
        let prompt_tokens = 64usize;
        // Gentler compression than the headline (same reasoning as the
        // swap section): the win margin is per-iteration service time.
        let ts_p = (cfg.time_scale / 4.0).max(1.0);
        let rms_p = bench_rms(&cascade, &cluster, prompt_tokens as f64 + steps_p as f64);
        // The draft model rides the verify tier's replica group (a
        // cross-tier pair colocates its draft), so its per-token cost
        // is the SHALLOW model at the DEEP tier's parallelism.
        let draft_s = ReplicaModel::new(
            &cascade[0],
            &cluster,
            8,
            1,
            prompt_tokens as f64 + steps_p as f64,
        )
        .decode_iteration(1);
        // ~60% of the off arm's serial (tier 0 + tier 1) capacity.
        let service = rms_p[0].prefill_latency(prompt_tokens as f64)
            + steps_p as f64 * rms_p[0].decode_iteration(1)
            + rms_p[1].prefill_latency(prompt_tokens as f64)
            + steps_p as f64 * rms_p[1].decode_iteration(1);
        let rate = 0.6 / service.max(1e-9);
        let reqs: Vec<Request> = {
            let mut spec_t = paper_trace(1, 1.0);
            spec_t.burstiness = 1.0;
            crate::workload::generate(&spec_t, n, cfg.seed.wrapping_add(13))
        };
        let strace: Vec<TraceEntry> = (0..n)
            .map(|i| {
                let mut prompt: Vec<i32> =
                    (0..prompt_tokens - 1).map(|j| tail_token(i + 700_000, j)).collect();
                prompt.push(i as i32);
                TraceEntry { at: i as f64 / rate / ts_p, prompt, max_new: Some(steps_p) }
            })
            .collect();
        let pjudger = BenchJudger {
            requests: reqs,
            models: cascade.clone(),
            judger: Judger::new(cfg.seed.wrapping_add(13)),
        };
        let arm = |speculation: Vec<Option<SpecSpec>>,
                   spec_backend: Option<(f64, u64)>|
         -> Result<ContinuousRun> {
            run_continuous(
                &strace,
                &pjudger,
                &rms_p,
                vec![1, 1],
                vec![4, 4],
                crate::router::THRESHOLD_MAX,
                steps_p,
                cfg.page_tokens,
                cfg.prefill_chunk,
                false,
                None,
                PreemptionMode::Recompute,
                Vec::new(),
                speculation,
                spec_backend,
                ts_p,
                1.0,
                None,
            )
        };
        let off = arm(Vec::new(), None).context("spec-section off run")?;
        let on = arm(
            vec![None, Some(SpecSpec { draft_k: k, acceptance: 1.0 })],
            Some((draft_s, 0)),
        )
        .context("spec-section on run")?;
        all_occupancy_ok = all_occupancy_ok
            && occupancy_ok(&off.stats.engine)
            && occupancy_ok(&on.stats.engine);
        let triples = |s: &ServerStats| -> Vec<(usize, usize, Vec<i32>)> {
            let mut v: Vec<_> = s
                .completions
                .iter()
                .map(|c| (c.id, c.accepting_tier, c.output.clone()))
                .collect();
            v.sort();
            v
        };
        let outputs_match = triples(&off.stats) == triples(&on.stats);
        let off_p95 = off.stats.p95_latency() * ts_p;
        let on_p95 = on.stats.p95_latency() * ts_p;
        let off_deep = off.stats.engine[1].iterations;
        let on_deep = on.stats.engine[1].iterations;
        let accepted = on.stats.engine[1].spec_accepted_tokens;
        let rejected = on.stats.engine[1].spec_rejected_tokens;
        SpecReport {
            requests: n,
            draft_k: k,
            off_p95_s: off_p95,
            spec_p95_s: on_p95,
            p95_speedup: off_p95 / on_p95.max(1e-9),
            off_deep_iterations: off_deep,
            spec_deep_iterations: on_deep,
            accepted_tokens: accepted,
            rejected_tokens: rejected,
            outputs_match,
            win: off.stats.completions.len() == n
                && on.stats.completions.len() == n
                && outputs_match
                && accepted > 0
                && on_deep < off_deep
                && on_p95 < off_p95,
        }
    };

    // --- Tracing section: the headline trace re-served on the
    // continuous engine with the span recorder + metrics registry
    // detached vs attached. Both runs use identical configs; only the
    // telemetry handle differs, so the delta is pure recording cost. ---
    let (tracing, profile) = {
        let off = run_continuous(
            &trace,
            &judger,
            &rms,
            replicas.clone(),
            max_batch.clone(),
            cfg.threshold,
            cfg.decode_steps,
            cfg.page_tokens,
            cfg.prefill_chunk,
            false,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            cfg.time_scale,
            cfg.token_scale as f64,
            None,
        )
        .context("tracing-off run")?;
        let telem = ServeTelemetry::for_tiers(replicas.len());
        let on = run_continuous(
            &trace,
            &judger,
            &rms,
            replicas.clone(),
            max_batch.clone(),
            cfg.threshold,
            cfg.decode_steps,
            cfg.page_tokens,
            cfg.prefill_chunk,
            false,
            None,
            PreemptionMode::Recompute,
            Vec::new(),
            Vec::new(),
            None,
            cfg.time_scale,
            cfg.token_scale as f64,
            Some(Arc::clone(&telem)),
        )
        .context("tracing-on run")?;
        all_occupancy_ok = all_occupancy_ok
            && occupancy_ok(&off.stats.engine)
            && occupancy_ok(&on.stats.engine);
        let p95_off = off.stats.p95_latency() * cfg.time_scale;
        let p95_on = on.stats.p95_latency() * cfg.time_scale;
        let events = telem.recorder.n_events();
        let dropped = telem.recorder.dropped_events() as usize;
        // 10 ms of compressed wall-clock jitter, expressed in
        // uncompressed seconds: time compression multiplies OS
        // scheduling noise by the same factor it divides latencies.
        let slack = 0.010 * cfg.time_scale;
        let tracing = TracingReport {
            requests: trace.len(),
            p95_off_s: p95_off,
            p95_on_s: p95_on,
            overhead_frac: (p95_on - p95_off) / p95_off.max(1e-9),
            events_recorded: events,
            dropped_events: dropped,
            win: p95_on <= p95_off * 1.03 + slack
                && events >= trace.len()
                && dropped == 0,
        };

        // --- Profile section: fold the tracing-on run's event stream
        // into phase waterfalls and gate (a) the fold's wall-clock
        // against the traced run's wall-clock, (b) how exactly the
        // waterfalls reconstruct measured e2e latency. ---
        let evs = telem.recorder.snapshot();
        let fold_t0 = std::time::Instant::now();
        let mut agg = crate::obs::ProfileAggregator::fold(
            crate::obs::ProfileConfig::default(),
            &evs,
        );
        let preport = agg.report(telem.recorder.dropped_events());
        let fold_wall_s = fold_t0.elapsed().as_secs_f64();
        let run_wall_s = on.stats.wall_clock.as_secs_f64();
        let fold_frac = fold_wall_s / run_wall_s.max(1e-9);
        let profile = ProfileSectionReport {
            requests: preport.requests,
            matched: preport.attribution_matched,
            events_folded: preport.events,
            fold_wall_s,
            run_wall_s,
            fold_frac,
            p95_err_s: preport.attribution_p95_err_s,
            p95_err_frac: preport.attribution_p95_err_frac,
            win: preport.attribution_matched > 0
                && (fold_frac <= 0.03 || fold_wall_s <= 0.010)
                && preport.attribution_p95_err_frac <= 0.05,
        };
        (tracing, profile)
    };

    Ok(BenchReport {
        calm_rate,
        burst_rate,
        n_requests: phased.requests.len(),
        burstiness: cfg.burstiness,
        lockstep,
        continuous,
        p95_speedup,
        throughput_gain,
        occupancy_ok: all_occupancy_ok,
        win,
        prefix,
        chunked,
        swap,
        disagg,
        spec,
        tracing,
        profile,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_sections_all_win_within_budget() {
        // A sub-smoke run (CI test budget): the engine must beat the
        // lockstep baseline, prefix sharing must cut pages and
        // re-prefill cost, chunked prefill must cut p95 TTFT, and page
        // occupancy must stay inside every pool.
        let cfg = BenchConfig {
            calm_requests: 16,
            burst_requests: 36,
            time_scale: 400.0,
            prefix_requests: 40,
            mix_short_requests: 32,
            mix_long_requests: 1,
            swap_requests: 8,
            disagg_requests: 16,
            ..BenchConfig::smoke()
        };
        let report = run_serving_bench(&cfg).unwrap();
        assert_eq!(report.lockstep.served, 52);
        assert_eq!(report.continuous.served, 52);
        assert!(report.occupancy_ok, "page occupancy exceeded a pool budget");
        for e in &report.continuous.engine {
            assert!(e.iterations > 0);
            assert!(e.peak_pages > 0);
        }
        assert!(
            report.win,
            "continuous must win: p95 speedup {:.2}, throughput gain {:.2}",
            report.p95_speedup, report.throughput_gain
        );
        assert!(
            report.prefix.win,
            "prefix sharing must cut pages ({} vs {}) and prefill ({} vs {})",
            report.prefix.shared_peak_pages,
            report.prefix.baseline_peak_pages,
            report.prefix.shared_prefill_tokens,
            report.prefix.baseline_prefill_tokens
        );
        assert!(report.prefix.prefix_hit_tokens > 0);
        assert!(
            report.chunked.win,
            "chunked prefill must cut p95 TTFT ({:.3}s vs {:.3}s)",
            report.chunked.chunked_p95_ttft_s, report.chunked.whole_p95_ttft_s
        );
        assert!(
            report.swap.preemptions > 0,
            "the swap-section trace must be preemption-heavy"
        );
        assert!(report.swap.swap_outs > 0, "swap mode must park victims");
        assert!(
            report.swap.swap_prefill_tokens
                == report.swap.requests * report.swap.prompt_tokens,
            "checkpointed resume prefills each prompt exactly once"
        );
        assert!(
            report.swap.win,
            "swap must beat recompute: p95 {:.3}s vs {:.3}s, prefilled {} vs {}",
            report.swap.swap_p95_s,
            report.swap.recompute_p95_s,
            report.swap.swap_prefill_tokens,
            report.swap.recompute_prefill_tokens
        );
        assert!(
            report.disagg.migrations > 0,
            "the split run must hand sequences off prefill→decode"
        );
        assert!(report.disagg.migrate_pages > 0);
        assert!(
            report.disagg.win,
            "the split must beat unified on p95 TTFT ({:.3}s vs {:.3}s, {} migrations)",
            report.disagg.disagg_p95_ttft_s,
            report.disagg.unified_p95_ttft_s,
            report.disagg.migrations
        );
        assert!(
            report.spec.accepted_tokens > 0,
            "agreement-heavy drafts must be accepted: {:?}",
            report.spec
        );
        assert!(
            report.spec.outputs_match,
            "speculation must be lossless: on/off outputs diverged"
        );
        assert!(
            report.spec.spec_deep_iterations < report.spec.off_deep_iterations,
            "accepted drafts must cut deep-tier iterations ({} vs {})",
            report.spec.spec_deep_iterations,
            report.spec.off_deep_iterations
        );
        assert!(
            report.spec.win,
            "speculation must win: p95 {:.3}s vs {:.3}s, deep iters {} vs {}",
            report.spec.spec_p95_s,
            report.spec.off_p95_s,
            report.spec.spec_deep_iterations,
            report.spec.off_deep_iterations
        );
        assert!(
            report.tracing.events_recorded >= report.tracing.requests,
            "tracing-on run must record at least one event per request"
        );
        assert_eq!(report.tracing.dropped_events, 0);
        assert!(
            report.tracing.win,
            "tracing must be within the overhead budget: p95 on {:.3}s vs off {:.3}s",
            report.tracing.p95_on_s, report.tracing.p95_off_s
        );
        assert_eq!(
            report.profile.requests, 52,
            "every served request must fold to a waterfall"
        );
        assert_eq!(
            report.profile.matched, 52,
            "every waterfall must open with an admitted event"
        );
        assert!(
            report.profile.win,
            "profile fold must stay in budget: fold {:.4}s of a {:.4}s run, p95 err frac {:.4}",
            report.profile.fold_wall_s,
            report.profile.run_wall_s,
            report.profile.p95_err_frac
        );
        assert!(report.all_green());
        // The report serializes with the fields CI greps for.
        let json = report.to_json().to_string();
        assert!(json.contains("\"win\":true"));
        assert!(json.contains("\"occupancy_ok\":true"));
        assert!(json.contains("\"prefix\""));
        assert!(json.contains("\"chunked\""));
        assert!(json.contains("\"swap\""));
        assert!(json.contains("\"disagg\""));
        assert!(json.contains("\"ttft_p95_speedup\""));
        assert!(json.contains("\"spec\""));
        assert!(json.contains("\"outputs_match\":true"));
        assert!(json.contains("\"accepted_tokens\""));
        assert!(json.contains("\"tracing\""));
        assert!(json.contains("\"overhead_ok\":true"));
        assert!(json.contains("\"profile\""));
        assert!(json.contains("\"fold_ok\":true"));
    }
}
