//! Cross-tier speculative decoding support.
//!
//! The cascade co-locates a cheap and an expensive model per the
//! deployment plan; speculation lets the shallow tier *accelerate* the
//! deep tier instead of only filtering for it: draft `k` tokens on the
//! small model, verify them in ONE deep-model step, emit the accepted
//! prefix plus the verifier's own next token. Every emitted token is a
//! verify-model token, so the output stream is bit-identical to the
//! deep model decoding alone — the **losslessness contract** the test
//! harness pins.
//!
//! Two pieces live here:
//!
//! * [`draft_agrees`] — the deterministic acceptance function shared by
//!   the paged DES ([`crate::sim::DesMode::Paged`]) and deterministic
//!   test backends, so accepted/rejected draft-token counts match
//!   bit-for-bit across the DES↔live equivalence pin;
//! * [`SpecPair`] — a draft+verify [`TierBackend`] pair adapted into a
//!   [`StepBackend`]: the bridge that gives whole-request backends
//!   (which have no native draft/verify) a speculative execution path.
//!   [`crate::coordinator::server::CascadeServer`] builds one per
//!   speculation-enabled worker from the tier's own factory and the
//!   factory of the tier below it.
//!
//! This module is inside the determinism lint scope: no wall clocks, no
//! ambient randomness — acceptance is a pure function of (sequence,
//! position), which is what makes the DES pin possible at all.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::server::TierBackend;

use super::core::{StepBackend, VerifyOutcome};
use super::kv::SeqId;

/// Deterministic draft/verify agreement: does the draft model's token
/// at global position `pos` of sequence `key` match the verify model's?
/// `agree_mod == 0` means perfect agreement; otherwise every
/// `agree_mod`-th position (keyed by a multiplicative hash so the
/// pattern varies per sequence) disagrees. Pure — the DES and
/// deterministic test backends share it so accepted-token counts line
/// up tick-for-tick.
pub fn draft_agrees(key: u64, pos: usize, agree_mod: u64) -> bool {
    if agree_mod == 0 {
        return true;
    }
    if agree_mod == 1 {
        return false;
    }
    key.wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(pos as u64)
        % agree_mod
        != 0
}

/// Per-sequence context a [`SpecPair`] tracks: the prompt as prefilled
/// so far and every *verified* token emitted.
#[derive(Debug, Default)]
struct PairSeq {
    prompt: Vec<i32>,
    out: Vec<i32>,
}

/// A tier-pair backend for cross-tier speculative decoding: `draft` is
/// the shallow tier's backend, `verify` the deep tier's. Both are
/// driven through their whole-request `generate` over the tracked
/// context, so any [`TierBackend`] works unchanged; losslessness holds
/// whenever the verify backend is *prefix-consistent* (greedy:
/// `generate(ctx, n)` extended one token equals
/// `generate(ctx ++ generate(ctx, n), 1)` prepended with it), which
/// deterministic backends are by construction.
///
/// Emitted tokens are always taken from the VERIFY model's stream —
/// the draft model only proposes; a rejected proposal costs nothing
/// but the draft compute.
pub struct SpecPair {
    draft: Box<dyn TierBackend>,
    verify: Box<dyn TierBackend>,
    seqs: BTreeMap<SeqId, PairSeq>,
}

impl SpecPair {
    pub fn new(draft: Box<dyn TierBackend>, verify: Box<dyn TierBackend>) -> SpecPair {
        SpecPair { draft, verify, seqs: BTreeMap::new() }
    }

    /// Verify-model continuation of `seq`'s tracked context.
    fn continue_verify(&mut self, seq: SeqId, n: usize) -> Result<Vec<i32>> {
        let st = self.seqs.entry(seq).or_default();
        let mut ctx = st.prompt.clone();
        ctx.extend_from_slice(&st.out);
        self.verify.generate(&ctx, n)
    }
}

impl StepBackend for SpecPair {
    fn prefill_chunk(&mut self, seq: SeqId, chunk: &[i32], last: bool) -> Result<Option<i32>> {
        // A recompute-preempted sequence was `release`d by the engine
        // before re-prefilling, so the tracked context always restarts
        // empty here; chunks accumulate in order.
        self.seqs.entry(seq).or_default().prompt.extend_from_slice(chunk);
        if !last {
            return Ok(None);
        }
        let first = self.continue_verify(seq, 1)?.into_iter().next();
        if let Some(t) = first {
            self.seqs.entry(seq).or_default().out.push(t);
        }
        Ok(first)
    }

    fn decode(&mut self, seqs: &[SeqId]) -> Result<Vec<i32>> {
        let mut toks = Vec::with_capacity(seqs.len());
        for &seq in seqs {
            let t = self
                .continue_verify(seq, 1)?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("verify backend produced no token for {seq}"))?;
            self.seqs.entry(seq).or_default().out.push(t);
            toks.push(t);
        }
        Ok(toks)
    }

    fn release(&mut self, seq: SeqId) {
        self.seqs.remove(&seq);
    }

    fn draft(&mut self, seq: SeqId, k: usize) -> Result<Option<Vec<i32>>> {
        let st = self.seqs.entry(seq).or_default();
        let mut ctx = st.prompt.clone();
        ctx.extend_from_slice(&st.out);
        let proposal = self.draft.generate(&ctx, k)?;
        Ok((!proposal.is_empty()).then_some(proposal))
    }

    fn verify(&mut self, seq: SeqId, draft: &[i32]) -> Result<Option<VerifyOutcome>> {
        let full = self.continue_verify(seq, draft.len() + 1)?;
        if full.is_empty() {
            return Ok(None);
        }
        // Longest common prefix, capped so the bonus token exists even
        // when the verify backend returned fewer tokens than asked.
        let mut accepted = 0usize;
        while accepted < draft.len()
            && accepted < full.len().saturating_sub(1)
            && full[accepted] == draft[accepted]
        {
            accepted += 1;
        }
        let next = full[accepted];
        let st = self.seqs.entry(seq).or_default();
        st.out.extend_from_slice(&full[..accepted]);
        st.out.push(next);
        Ok(Some(VerifyOutcome { accepted, next }))
    }
}

impl TierBackend for SpecPair {
    fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        // The pair is always stepped; a direct generate just proxies
        // the verify model (lossless by definition).
        self.verify.generate(prompt, max_new)
    }

    fn step_backend(&mut self) -> Option<&mut dyn StepBackend> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Emits a deterministic per-(prompt, position) stream; a nonzero
    /// `disagree_mod` makes a "draft" variant disagree at positions
    /// picked by [`draft_agrees`].
    struct StreamBackend {
        mark: i32,
        disagree_mod: u64,
    }

    impl StreamBackend {
        fn token(&self, prompt: &[i32], pos: usize) -> i32 {
            let base = prompt.first().copied().unwrap_or(0);
            base + self.mark + pos as i32
        }
    }

    impl TierBackend for StreamBackend {
        fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
            // `prompt` here is the full context; position indexes from
            // its length so the stream is prefix-consistent.
            Ok((0..max_new)
                .map(|i| {
                    let pos = prompt.len() + i;
                    let t = self.token(&prompt[..1.min(prompt.len())], pos);
                    if draft_agrees(prompt.first().copied().unwrap_or(0) as u64, pos, self.disagree_mod)
                    {
                        t
                    } else {
                        t + 1000 // a wrong draft token
                    }
                })
                .collect())
        }
    }

    #[test]
    fn agreement_function_is_deterministic_and_respects_mod() {
        assert!(draft_agrees(7, 3, 0), "mod 0 = perfect agreement");
        assert!(!draft_agrees(7, 3, 1), "mod 1 = never agrees");
        for key in 0..8u64 {
            for pos in 0..64usize {
                assert_eq!(
                    draft_agrees(key, pos, 4),
                    draft_agrees(key, pos, 4),
                    "pure function"
                );
            }
        }
        // Roughly one in `m` positions disagrees.
        let misses = (0..400).filter(|&p| !draft_agrees(3, p, 4)).count();
        assert!((80..=120).contains(&misses), "~100 expected, got {misses}");
    }

    #[test]
    fn spec_pair_emits_exactly_the_verify_stream() {
        let mk = || {
            SpecPair::new(
                Box::new(StreamBackend { mark: 0, disagree_mod: 3 }),
                Box::new(StreamBackend { mark: 0, disagree_mod: 0 }),
            )
        };
        // Reference: plain decode, token by token.
        let mut plain = mk();
        let prompt = vec![5, 6, 7];
        let first = plain.prefill_chunk(1, &prompt, true).unwrap().unwrap();
        let mut reference = vec![first];
        for _ in 0..7 {
            reference.push(plain.decode(&[1]).unwrap()[0]);
        }
        // Speculative: draft 3, verify, repeat.
        let mut spec = mk();
        let first = spec.prefill_chunk(1, &prompt, true).unwrap().unwrap();
        let mut out = vec![first];
        let mut accepted_total = 0usize;
        while out.len() < 8 {
            let drafts = spec.draft(1, 3).unwrap().unwrap();
            let v = spec.verify(1, &drafts).unwrap().unwrap();
            out.extend_from_slice(&drafts[..v.accepted]);
            out.push(v.next);
            accepted_total += v.accepted;
        }
        out.truncate(8);
        assert_eq!(out, reference, "lossless: speculative == plain verify stream");
        assert!(accepted_total > 0, "the imperfect draft still lands accepts");
    }
}
