//! Continuous-batching execution engine with paged KV-cache
//! management.
//!
//! The deployment level models prefill as compute-bound and decode as
//! memory-bound (§3), but a whole-batch serving loop wastes both: short
//! requests wait on long batchmates and the KV budget is enforced only
//! as a static request count. This subsystem makes the scheduler's
//! memory model real at runtime:
//!
//! * [`KvPool`] (`kv`) — fixed-size token pages with refcounted
//!   per-sequence page tables, a prefix trie over chained token-page
//!   hashes for shared-prompt serving (claim at admission, publish
//!   after prefill, copy-on-write on first divergent write),
//!   alloc/free/defrag/leak accounting, live resize;
//! * [`IterationScheduler`] (`scheduler`) — each tick retires finished
//!   sequences, interleaves budgeted prefill chunks with decode
//!   (Sarathi-style `prefill_chunk` token budget), admits queued
//!   requests FIFO while pages remain (claiming published prefixes
//!   first — a full hit skips prefill entirely), and evicts
//!   newest-first on pool exhaustion, choosing per victim between
//!   preempt-with-recompute and swap-to-host with chunk-checkpointed
//!   resume ([`PreemptionConfig`]: recompute cost = resident tokens ×
//!   prefill rate vs swap cost = private pages × 2 × PCIe page time;
//!   parked sequences resume ahead of new admissions);
//! * [`EngineCore`] (`core`) — the per-worker loop behind the existing
//!   `TierBackend` trait: native [`StepBackend`]s step token-by-token
//!   (calibrated simulated backends charge
//!   [`crate::perf::ReplicaModel::decode_iteration`] at the live batch
//!   size), whole-request backends are adapted transparently;
//! * [`SpecPair`] (`spec`) — cross-tier speculative decoding: a
//!   shallow-tier draft backend paired with the deep tier's verify
//!   backend behind one [`StepBackend`], lossless by construction
//!   (every emitted token comes from the verify model), scheduled as
//!   per-tick draft→verify tasks with rejected-page rollback;
//! * `bench` — the calibrated lockstep-vs-continuous serving benchmark
//!   behind `cascadia bench` (writes `BENCH_serving.json`).
//!
//! The same scheduler drives the paged mode of the discrete-event
//! simulator ([`crate::sim::des`]), so schedule-time estimates and the
//! runtime share one admission/preemption policy, and
//! [`crate::coordinator::server::ExecMode::Continuous`] threads the
//! engine through the live serving path with hot-swappable pool sizing
//! (see [`crate::adapt`]).

pub mod bench;
pub mod core;
pub mod kv;
pub mod migrate;
pub mod scheduler;
pub mod spec;

pub use bench::{run_serving_bench, BenchConfig, BenchReport, TracingReport};
pub use core::{EngineConfig, EngineCore, Finished, StepBackend, StepOutcome, VerifyOutcome};
pub use kv::{prompt_page_hashes, KvPool, PagesShort, SeqId, SwapShort};
pub use migrate::{MigratedSeq, MigrationHub};
pub use scheduler::{
    ChunkTask, EngineRole, IterationPlan, IterationScheduler, PreemptionConfig, PreemptionMode,
    SpecTask,
};
pub use spec::{draft_agrees, SpecPair};
