//! Iteration-level scheduler: which sequences run in the next decode
//! iteration, against the paged KV pool.
//!
//! Each call to [`IterationScheduler::next_iteration`] is one engine
//! tick:
//!
//! 1. **publish** — sequences whose prefill completed in an earlier
//!    tick publish their prompt pages into the pool's prefix trie
//!    ([`KvPool::publish_prefix`]), so later admissions with the same
//!    prompt prefix can claim them;
//! 2. **grow** — every decoding sequence is about to produce one more
//!    token, so its context grows by one; pages for the growth are
//!    reserved oldest-first. On pool exhaustion the *newest* running
//!    sequence is preempted (vLLM's recompute policy: its pages are
//!    freed, its progress — including partial prefill — resets, and it
//!    re-queues at the *front* of the wait queue so FIFO order is
//!    preserved);
//! 3. **prefill** — sequences still prefilling get the next chunk of
//!    their prompt, oldest first, under the per-tick token budget
//!    (`prefill_chunk`, Sarathi-style): long prompts are spread over
//!    several iterations interleaved with decode instead of charging
//!    the whole prompt into one admission tick. The chunk that
//!    completes a prompt also produces the first token;
//! 4. **admit** — waiting sequences are admitted strictly FIFO while
//!    the pool has pages and the running set is under `max_running`.
//!    Admission first walks the prefix trie ([`KvPool::claim_prefix`]):
//!    claimed tokens need neither pages nor prefill compute, and a
//!    full-prompt hit (a cascade re-serve, a same-prompt retry) skips
//!    prefill entirely and decodes its first token this very tick.
//!
//! The scheduler never deadlocks: when a sequence cannot fit even with
//! every other sequence preempted (the pool is smaller than one
//! request), the pool is force-expanded to hold it and the expansion is
//! counted — a misconfigured pool degrades with accounting instead of
//! wedging the engine. Completion bookkeeping ([`advance`]/[`retire`])
//! lives here too so the paged discrete-event simulator can drive the
//! *same* scheduler the live engine runs (see [`crate::sim::des`]).
//!
//! [`advance`]: IterationScheduler::advance
//! [`retire`]: IterationScheduler::retire

use std::collections::{HashMap, VecDeque};

use super::kv::{KvPool, SeqId};

/// Token bookkeeping of one tracked sequence.
#[derive(Debug, Clone)]
struct Seq {
    prompt_tokens: usize,
    max_new: usize,
    /// Tokens generated since (re-)admission; preemption resets this
    /// (recompute semantics).
    generated: usize,
    /// Prompt tokens whose KV is resident (claimed prefix + prefill
    /// chunks done); preemption resets this too.
    prefilled: usize,
    /// Prompt pages published into the prefix trie (or inherited via a
    /// full claim).
    published: bool,
    /// Chained page hashes of the prompt (empty = sharing disabled).
    hashes: Vec<u64>,
}

impl Seq {
    fn decoding(&self) -> bool {
        self.prefilled >= self.prompt_tokens
    }
}

/// One prefill chunk scheduled into an iteration: process prompt
/// tokens `start .. start + len` of sequence `id`. `last` marks the
/// chunk that completes the prompt — it produces the first token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkTask {
    pub id: SeqId,
    pub start: usize,
    pub len: usize,
    pub last: bool,
}

/// One planned engine iteration.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    /// Sequences newly admitted this tick that owe prefill work (their
    /// first chunk is in `prefill`). Full-prefix-hit admissions appear
    /// in `decode` instead — their KV is already resident.
    pub admitted: Vec<SeqId>,
    /// Prefill chunks to process this tick (newly admitted sequences'
    /// first chunks and carried-over partial prefills). A `last` chunk
    /// produces the sequence's first token.
    pub prefill: Vec<ChunkTask>,
    /// Fully-prefilled sequences advancing one decode token.
    pub decode: Vec<SeqId>,
    /// Sequences preempted this tick. Their KV pages are already freed
    /// and their progress (decode *and* partial prefill) reset; callers
    /// must drop any per-sequence backend state (they re-prefill on
    /// re-admission).
    pub preempted: Vec<SeqId>,
    /// Forced pool expansions this tick (0 unless the pool was smaller
    /// than a single sequence).
    pub forced_expansions: usize,
}

impl IterationPlan {
    /// Total sequences occupying a batch slot this tick (decoding or
    /// prefilling).
    pub fn batch(&self) -> usize {
        self.prefill.len() + self.decode.len()
    }

    /// Prompt tokens of prefill work charged into this tick.
    pub fn prefill_tokens(&self) -> usize {
        self.prefill.iter().map(|c| c.len).sum()
    }

    /// Sequences producing one token this tick: every decoder plus
    /// every sequence whose *last* prefill chunk lands here.
    pub fn producers(&self) -> Vec<SeqId> {
        let mut v: Vec<SeqId> = self.decode.clone();
        v.extend(self.prefill.iter().filter(|c| c.last).map(|c| c.id));
        v
    }
}

/// FIFO iteration scheduler over a paged KV pool.
#[derive(Debug)]
pub struct IterationScheduler {
    pool: KvPool,
    waiting: VecDeque<SeqId>,
    /// Admission order, oldest first.
    running: Vec<SeqId>,
    seqs: HashMap<SeqId, Seq>,
    max_running: usize,
    /// Prefill token budget per iteration (`usize::MAX` = whole-prompt
    /// admission, the pre-chunking discipline).
    prefill_chunk: usize,
    preemptions: u64,
    forced_expansions: u64,
    prefix_hit_tokens: u64,
}

impl IterationScheduler {
    /// `max_running` bounds the running set by request count on top of
    /// the pool's page bound (use `usize::MAX` for pages-only).
    pub fn new(pool: KvPool, max_running: usize) -> IterationScheduler {
        IterationScheduler {
            pool,
            waiting: VecDeque::new(),
            running: Vec::new(),
            seqs: HashMap::new(),
            max_running: max_running.max(1),
            prefill_chunk: usize::MAX,
            preemptions: 0,
            forced_expansions: 0,
            prefix_hit_tokens: 0,
        }
    }

    /// Cap the prefill tokens charged into any one iteration (clamped
    /// to at least one page so every prefilling sequence can progress).
    pub fn set_prefill_chunk(&mut self, tokens: usize) {
        self.prefill_chunk = tokens.max(self.pool.page_tokens());
    }

    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Track a new sequence at the back of the wait queue.
    pub fn enqueue(&mut self, id: SeqId, prompt_tokens: usize, max_new: usize) {
        self.enqueue_shared(id, prompt_tokens, max_new, Vec::new());
    }

    /// Like [`IterationScheduler::enqueue`], with the prompt's chained
    /// page hashes ([`crate::engine::prompt_page_hashes`], computed at
    /// the pool's page size): admission will claim any published
    /// prefix and publish the prompt's pages once prefilled.
    pub fn enqueue_shared(
        &mut self,
        id: SeqId,
        prompt_tokens: usize,
        max_new: usize,
        hashes: Vec<u64>,
    ) {
        debug_assert!(!self.seqs.contains_key(&id), "duplicate sequence id");
        self.seqs.insert(
            id,
            Seq {
                prompt_tokens: prompt_tokens.max(1),
                max_new: max_new.max(1),
                generated: 0,
                prefilled: 0,
                published: false,
                hashes,
            },
        );
        self.waiting.push_back(id);
    }

    /// Waiting + running sequences.
    pub fn n_seqs(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.n_seqs() == 0
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Retarget the pool (hot-swap lever). Scale-down takes effect as
    /// sequences retire — see [`KvPool::resize`].
    pub fn resize_pool(&mut self, pages: usize) {
        self.pool.resize(pages);
    }

    pub fn max_running(&self) -> usize {
        self.max_running
    }

    pub fn set_max_running(&mut self, max_running: usize) {
        self.max_running = max_running.max(1);
    }

    /// Sequences preempted over the scheduler's lifetime.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Forced pool expansions over the scheduler's lifetime.
    pub fn forced_expansions(&self) -> u64 {
        self.forced_expansions
    }

    /// Prompt tokens served from shared prefix pages instead of being
    /// re-prefilled, over the scheduler's lifetime.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// Preempt `id`: free its pages, reset its progress (decode and
    /// partial prefill), and requeue it at the front of the wait queue.
    /// Work already planned for the victim THIS tick is withdrawn — a
    /// later reservation may evict a sequence that entered the decode
    /// or chunk lists earlier in the same planning pass.
    fn preempt(&mut self, id: SeqId, plan: &mut IterationPlan) {
        self.pool.release(id);
        if let Some(s) = self.seqs.get_mut(&id) {
            s.generated = 0;
            s.prefilled = 0;
            s.published = false;
        }
        self.waiting.push_front(id);
        plan.decode.retain(|&d| d != id);
        plan.prefill.retain(|c| c.id != id);
        plan.preempted.push(id);
        self.preemptions += 1;
    }

    /// Grow the pool just enough to cover a `short`-page shortfall even
    /// while over-committed (the no-deadlock escape hatch).
    fn force_expand(&mut self, short: usize, plan: &mut IterationPlan) {
        let want = (self.pool.in_use() + self.pool.free_pages() + short)
            .max(self.pool.capacity() + 1);
        self.pool.resize(want);
        self.forced_expansions += 1;
        plan.forced_expansions += 1;
    }

    /// Reserve pages so `id`'s context covers `tokens`, preempting the
    /// newest running sequence on exhaustion (or force-expanding when
    /// `id` runs alone). Returns false iff `id` preempted itself.
    fn reserve(&mut self, id: SeqId, tokens: usize, plan: &mut IterationPlan) -> bool {
        while let Err(short) = self.pool.grow_to(id, tokens) {
            if self.running.len() <= 1 {
                // Alone and still short: the pool cannot hold even
                // this one sequence.
                self.force_expand(short.0, plan);
            } else {
                let victim = self.running.pop().expect("len > 1");
                self.preempt(victim, plan);
                if victim == id {
                    return false;
                }
            }
        }
        true
    }

    /// Plan the next iteration. See the module docs for the policy.
    pub fn next_iteration(&mut self) -> IterationPlan {
        let mut plan = IterationPlan::default();

        // 0. Publish prompt pages of sequences whose prefill completed
        // in an earlier tick (their KV is computed by now).
        let publishable: Vec<SeqId> = self
            .running
            .iter()
            .copied()
            .filter(|id| {
                let s = &self.seqs[id];
                s.decoding() && !s.published
            })
            .collect();
        for id in publishable {
            let hashes = self.seqs[&id].hashes.clone();
            if !hashes.is_empty() {
                self.pool.publish_prefix(id, &hashes);
            }
            self.seqs.get_mut(&id).expect("running seq").published = true;
        }

        // 1. Reserve one token of growth per decoding sequence, oldest
        // first; preempt from the newest end on exhaustion.
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let s = &self.seqs[&id];
            if !s.decoding() {
                i += 1;
                continue;
            }
            let need = s.prompt_tokens + s.generated + 1;
            if self.reserve(id, need, &mut plan) {
                i += 1;
            }
        }

        // Surviving decoders advance one token this tick.
        plan.decode = self
            .running
            .iter()
            .copied()
            .filter(|id| self.seqs[id].decoding())
            .collect();

        // 2. Prefill chunks for carried-over partial prefills, oldest
        // first, under the tick's token budget.
        let mut budget = self.prefill_chunk;
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let s = &self.seqs[&id];
            if s.decoding() {
                i += 1;
                continue;
            }
            if budget == 0 {
                break;
            }
            let remaining = s.prompt_tokens - s.prefilled;
            let len = remaining.min(budget);
            let last = len == remaining;
            let start = s.prefilled;
            let need = start + len + usize::from(last);
            if self.reserve(id, need, &mut plan) {
                self.seqs.get_mut(&id).expect("running seq").prefilled = start + len;
                plan.prefill.push(ChunkTask { id, start, len, last });
                budget -= len;
                i += 1;
            }
        }

        // 3. Admit strictly FIFO while prefix-claimed-plus-first-chunk
        // contexts fit and budget remains.
        while self.running.len() < self.max_running {
            let Some(&head) = self.waiting.front() else { break };
            let prompt_tokens = self.seqs[&head].prompt_tokens;
            let claimed = if self.seqs[&head].hashes.is_empty() || self.pool.holds(head) {
                0
            } else {
                let s = &self.seqs[&head];
                self.pool.claim_prefix(head, &s.hashes, s.prompt_tokens)
            };
            if claimed >= prompt_tokens {
                // Full prefix hit (identical prompt re-served): no
                // prefill owed at all — decode the first token now.
                match self.pool.grow_to(head, prompt_tokens + 1) {
                    Ok(()) => {
                        self.waiting.pop_front();
                        self.running.push(head);
                        let s = self.seqs.get_mut(&head).expect("waiting seq");
                        s.prefilled = prompt_tokens;
                        s.published = true; // pages are already in the trie
                        self.prefix_hit_tokens += claimed as u64;
                        plan.decode.push(head);
                    }
                    Err(short) => {
                        self.pool.retract_claim(head);
                        if self.running.is_empty() {
                            self.force_expand(short.0, &mut plan);
                            continue;
                        }
                        break;
                    }
                }
                continue;
            }
            let remaining = prompt_tokens - claimed;
            if budget == 0 {
                // No prefill budget left this tick; undo the claim so
                // the head re-claims (possibly more) next tick.
                if claimed > 0 {
                    self.pool.retract_claim(head);
                }
                break;
            }
            let len = remaining.min(budget);
            let last = len == remaining;
            match self.pool.grow_to(head, claimed + len + usize::from(last)) {
                Ok(()) => {
                    self.waiting.pop_front();
                    self.running.push(head);
                    let s = self.seqs.get_mut(&head).expect("waiting seq");
                    s.prefilled = claimed + len;
                    self.prefix_hit_tokens += claimed as u64;
                    plan.admitted.push(head);
                    plan.prefill.push(ChunkTask { id: head, start: claimed, len, last });
                    budget -= len;
                }
                Err(short) => {
                    self.pool.retract_claim(head);
                    if self.running.is_empty() {
                        // Nothing running and the head alone does not
                        // fit: expand or the engine deadlocks.
                        self.force_expand(short.0, &mut plan);
                        continue;
                    }
                    break;
                }
            }
        }
        plan
    }

    /// Record one generated token for `id`; returns true when the
    /// sequence reached its token budget (caller should retire it).
    pub fn advance(&mut self, id: SeqId) -> bool {
        let s = self.seqs.get_mut(&id).expect("advance of unknown sequence");
        s.generated += 1;
        s.generated >= s.max_new
    }

    /// Drop a finished (or cancelled) sequence and free its pages.
    pub fn retire(&mut self, id: SeqId) {
        self.pool.release(id);
        if let Some(pos) = self.running.iter().position(|&r| r == id) {
            self.running.remove(pos);
        } else if let Some(pos) = self.waiting.iter().position(|&r| r == id) {
            let _ = self.waiting.remove(pos);
        }
        self.seqs.remove(&id);
    }

    /// Remove and return every tracked sequence (waiting first, then
    /// running, both FIFO), freeing all pages — the worker-death path.
    pub fn drain_ids(&mut self) -> Vec<SeqId> {
        let mut out: Vec<SeqId> = self.waiting.drain(..).collect();
        out.extend(self.running.drain(..));
        for &id in &out {
            self.pool.release(id);
        }
        self.seqs.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::kv::prompt_page_hashes;

    fn sched(pages: usize, page_tokens: usize, max_running: usize) -> IterationScheduler {
        IterationScheduler::new(KvPool::new(pages, page_tokens), max_running)
    }

    /// Drive the scheduler to completion, retiring sequences as they
    /// finish; returns (completion order, iterations used).
    fn run_to_completion(s: &mut IterationScheduler, max_iters: usize) -> (Vec<SeqId>, usize) {
        let mut order = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters <= max_iters, "scheduler failed to make progress");
            let plan = s.next_iteration();
            assert!(plan.batch() > 0, "a tick with sequences must advance something");
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    order.push(id);
                }
            }
        }
        (order, iters)
    }

    #[test]
    fn admission_is_fifo() {
        let mut s = sched(64, 16, 4);
        for id in 0..6u64 {
            s.enqueue(id, 16, 4);
        }
        let plan = s.next_iteration();
        assert_eq!(plan.admitted, vec![0, 1, 2, 3], "max_running caps the batch");
        assert!(plan.decode.is_empty());
        assert!(plan.prefill.iter().all(|c| c.last), "short prompts prefill whole");
        let plan2 = s.next_iteration();
        assert_eq!(plan2.decode, vec![0, 1, 2, 3]);
        assert!(plan2.admitted.is_empty(), "running set is full");
    }

    #[test]
    fn completion_frees_room_for_the_queue() {
        let mut s = sched(64, 16, 2);
        for id in 0..4u64 {
            s.enqueue(id, 8, 2);
        }
        let (order, _) = run_to_completion(&mut s, 64);
        assert_eq!(order, vec![0, 1, 2, 3], "FIFO completion under a count bound");
        assert_eq!(s.pool().in_use(), 0, "all pages returned");
        assert_eq!(s.preemptions(), 0);
    }

    #[test]
    fn pool_exhaustion_preempts_newest_and_requeues_front() {
        // 4 pages of 16 tokens; each seq needs 2 pages at admission
        // (prompt 17 -> 2 pages) and grows into a 3rd page later
        // (17 + 16 = 33 tokens -> 3 pages at generated = 16).
        let mut s = sched(4, 16, 8);
        s.enqueue(0, 17, 20);
        s.enqueue(1, 17, 20);
        let first = s.next_iteration();
        assert_eq!(first.admitted, vec![0, 1]);
        // Tick until growth forces a preemption: seq 1 (newest) must be
        // the victim, exactly once, and re-admit after 0 retires.
        let mut preempted_events: Vec<SeqId> = Vec::new();
        let mut done: Vec<SeqId> = Vec::new();
        let mut iters = 0;
        // Consume the first tick's tokens.
        for id in first.producers() {
            assert!(!s.advance(id));
        }
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 200, "no deadlock allowed");
            let plan = s.next_iteration();
            preempted_events.extend(&plan.preempted);
            assert!(plan.batch() > 0);
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1], "both sequences complete, oldest first");
        assert!(!preempted_events.is_empty(), "the tight pool must preempt");
        assert!(
            preempted_events.iter().all(|&id| id == 1),
            "only the newest sequence may be preempted: {preempted_events:?}"
        );
        assert_eq!(s.forced_expansions(), 0, "a sane pool never force-expands");
        assert!(s.pool().peak_in_use() <= 4, "occupancy may never exceed the pool");
    }

    #[test]
    fn many_sequences_tiny_pool_never_deadlocks() {
        let mut s = sched(6, 8, 64);
        for id in 0..12u64 {
            s.enqueue(id, 12, 24); // worst case 12+24 = 36 tokens = 5 pages
        }
        let (order, _) = run_to_completion(&mut s, 5_000);
        assert_eq!(order.len(), 12);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 12, "exactly-once completion");
        assert_eq!(s.forced_expansions(), 0);
        assert!(s.pool().peak_in_use() <= 6);
    }

    #[test]
    fn oversized_sequence_forces_expansion_instead_of_deadlock() {
        // Pool of 2 pages cannot hold a 100-token prompt (7 pages).
        let mut s = sched(2, 16, 4);
        s.enqueue(0, 100, 4);
        let (order, _) = run_to_completion(&mut s, 32);
        assert_eq!(order, vec![0]);
        assert!(s.forced_expansions() >= 1, "expansion must be accounted");
    }

    #[test]
    fn preempted_sequence_restarts_from_scratch() {
        let mut s = sched(4, 16, 8);
        s.enqueue(0, 17, 40);
        s.enqueue(1, 17, 40);
        let mut total_advances_for_1 = 0usize;
        let mut saw_preempt = false;
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 500);
            let plan = s.next_iteration();
            if plan.preempted.contains(&1) {
                saw_preempt = true;
            }
            for id in plan.producers() {
                if id == 1 {
                    total_advances_for_1 += 1;
                }
                if s.advance(id) {
                    s.retire(id);
                }
            }
        }
        assert!(saw_preempt);
        assert!(
            total_advances_for_1 > 40,
            "recompute must replay preempted progress ({total_advances_for_1} advances)"
        );
    }

    #[test]
    fn resize_down_blocks_admission_until_drain() {
        let mut s = sched(8, 16, 8);
        s.enqueue(0, 30, 4); // 2 pages minimum
        let plan = s.next_iteration();
        assert_eq!(plan.admitted, vec![0]);
        s.resize_pool(1); // below the running seq's footprint
        s.enqueue(1, 30, 4);
        // Seq 1 cannot be admitted while 0 holds the over-committed
        // pool, but 0 still runs (forced expansion only grows to cover
        // growth of the lone running seq).
        let plan2 = s.next_iteration();
        assert_eq!(plan2.decode, vec![0]);
        assert!(plan2.admitted.is_empty());
        (0..4).for_each(|_| {
            if s.advance(0) {
                s.retire(0);
            }
        });
        assert!(!s.running.contains(&0));
        // With 0 gone the pool drains; seq 1 admits (forced expansion
        // may fire because 1 page < one sequence).
        let plan3 = s.next_iteration();
        assert_eq!(plan3.admitted, vec![1]);
    }

    #[test]
    fn drain_returns_everything_and_frees_pages() {
        let mut s = sched(16, 16, 2);
        for id in 0..5u64 {
            s.enqueue(id, 16, 4);
        }
        let _ = s.next_iteration(); // admit 0, 1
        let ids = s.drain_ids();
        assert_eq!(ids.len(), 5);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        assert_eq!(s.pool().in_use(), 0);
        assert!(s.is_idle());
    }

    // ---- Chunked prefill ----

    #[test]
    fn long_prompt_prefills_in_budgeted_chunks() {
        let mut s = sched(64, 16, 8);
        s.set_prefill_chunk(32);
        s.enqueue(0, 100, 3);
        // Tick 1: admit + first 32-token chunk, no token produced.
        let p1 = s.next_iteration();
        assert_eq!(p1.admitted, vec![0]);
        assert_eq!(p1.prefill, vec![ChunkTask { id: 0, start: 0, len: 32, last: false }]);
        assert!(p1.decode.is_empty());
        assert!(p1.producers().is_empty(), "mid-prefill produces nothing");
        // Ticks 2-3: carried-over chunks.
        let p2 = s.next_iteration();
        assert_eq!(p2.prefill, vec![ChunkTask { id: 0, start: 32, len: 32, last: false }]);
        let p3 = s.next_iteration();
        assert_eq!(p3.prefill, vec![ChunkTask { id: 0, start: 64, len: 32, last: false }]);
        // Tick 4: the last 4 tokens complete prefill -> first token.
        let p4 = s.next_iteration();
        assert_eq!(p4.prefill, vec![ChunkTask { id: 0, start: 96, len: 4, last: true }]);
        assert_eq!(p4.producers(), vec![0]);
        assert!(!s.advance(0));
        // From here on it decodes.
        let p5 = s.next_iteration();
        assert_eq!(p5.decode, vec![0]);
        assert!(p5.prefill.is_empty());
    }

    #[test]
    fn chunk_budget_interleaves_prefill_with_decode() {
        let mut s = sched(64, 16, 8);
        s.set_prefill_chunk(16);
        s.enqueue(0, 8, 8); // short: decodes immediately
        let p = s.next_iteration();
        assert!(!s.advance(0));
        assert_eq!(p.producers(), vec![0]);
        s.enqueue(1, 64, 4); // long: 4 chunks of 16
        for tick in 0..4 {
            let p = s.next_iteration();
            assert_eq!(p.decode, vec![0], "decode keeps running during prefill (tick {tick})");
            assert_eq!(p.prefill.len(), 1);
            assert_eq!(p.prefill[0].len, 16);
            assert!(!s.advance(0));
            if p.prefill[0].last {
                assert!(!s.advance(1));
            }
        }
        // Both now decode together.
        let p = s.next_iteration();
        assert_eq!(p.decode, vec![0, 1]);
    }

    #[test]
    fn chunk_budget_is_shared_across_admissions() {
        let mut s = sched(64, 16, 8);
        s.set_prefill_chunk(48);
        for id in 0..3u64 {
            s.enqueue(id, 32, 2);
        }
        // 48-token budget covers seq 0 (32) and half of seq 1 (16);
        // seq 2 must wait for budget even though pages are free.
        let p1 = s.next_iteration();
        assert_eq!(p1.admitted, vec![0, 1]);
        assert_eq!(p1.prefill[0], ChunkTask { id: 0, start: 0, len: 32, last: true });
        assert_eq!(p1.prefill[1], ChunkTask { id: 1, start: 0, len: 16, last: false });
        assert!(!s.advance(0));
        let p2 = s.next_iteration();
        assert_eq!(p2.admitted, vec![2]);
        assert_eq!(p2.prefill[0], ChunkTask { id: 1, start: 16, len: 16, last: true });
        assert_eq!(p2.prefill[1], ChunkTask { id: 2, start: 0, len: 32, last: true });
    }

    #[test]
    fn preempted_partial_prefill_restarts_cleanly() {
        // Tight pool: a long prompt mid-prefill is preempted by the
        // older decoder's growth and must re-prefill from scratch.
        let mut s = sched(4, 16, 8);
        s.set_prefill_chunk(16);
        s.enqueue(0, 17, 24); // 2 pages, grows to 3
        s.enqueue(1, 40, 2); // 3 pages over 3 chunks
        let mut chunks_for_1: Vec<ChunkTask> = Vec::new();
        let mut done = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 300, "no deadlock");
            let plan = s.next_iteration();
            chunks_for_1.extend(plan.prefill.iter().filter(|c| c.id == 1));
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1]);
        assert!(s.preemptions() > 0, "the tight pool must preempt the prefill");
        // After each preemption the chunk offsets restart at 0.
        let restarts = chunks_for_1.iter().filter(|c| c.start == 0).count();
        assert!(restarts >= 2, "re-admission must re-prefill from scratch");
        assert_eq!(s.pool().in_use(), 0);
        assert_eq!(s.pool().trie_len(), 0);
    }

    // ---- Prefix sharing through the scheduler ----

    fn hashes_of(seed: i32, len: usize, pt: usize) -> Vec<u64> {
        let prompt: Vec<i32> =
            (0..len as i32).map(|i| seed.wrapping_mul(977).wrapping_add(i)).collect();
        prompt_page_hashes(&prompt, pt)
    }

    #[test]
    fn full_prefix_hit_skips_prefill_entirely() {
        let mut s = sched(64, 16, 8);
        let h = hashes_of(1, 48, 16);
        s.enqueue_shared(0, 48, 4, h.clone());
        let p1 = s.next_iteration();
        assert_eq!(p1.admitted, vec![0]);
        assert_eq!(p1.prefill_tokens(), 48, "first serve prefills everything");
        assert!(!s.advance(0));
        let _ = s.next_iteration(); // publishes seq 0's pages
        // An identical prompt (a cascade re-serve) claims every page:
        // no prefill chunk, first token decoded immediately.
        s.enqueue_shared(1, 48, 4, h);
        let p = s.next_iteration();
        assert!(p.admitted.is_empty(), "full hits owe no prefill");
        assert!(p.decode.contains(&1));
        assert!(p.prefill.is_empty());
        assert_eq!(s.prefix_hit_tokens(), 48);
        assert!(!s.advance(1));
        // Physical occupancy: 48-token prompt = 3 pages shared + one
        // private first-token page each.
        assert!(s.pool().in_use() <= 3 + 2, "shared pages must not be duplicated");
    }

    #[test]
    fn partial_prefix_hit_prefills_only_the_tail() {
        let mut s = sched(64, 16, 8);
        // Two prompts sharing the first 32 tokens (2 pages), diverging
        // in the tail page.
        let shared: Vec<i32> = (0..32).collect();
        let mut a = shared.clone();
        a.extend(100..116);
        let mut b = shared;
        b.extend(200..216);
        s.enqueue_shared(0, 48, 4, prompt_page_hashes(&a, 16));
        let _ = s.next_iteration();
        assert!(!s.advance(0));
        let _ = s.next_iteration(); // publish
        s.enqueue_shared(1, 48, 4, prompt_page_hashes(&b, 16));
        let p = s.next_iteration();
        let chunk = p.prefill.iter().find(|c| c.id == 1).expect("tail chunk");
        assert_eq!(chunk.start, 32, "shared pages skip prefill");
        assert_eq!(chunk.len, 16);
        assert!(chunk.last);
        assert_eq!(s.prefix_hit_tokens(), 32);
    }

    #[test]
    fn retire_and_drain_leave_no_shared_residue() {
        let mut s = sched(32, 16, 8);
        let h = hashes_of(7, 64, 16);
        let free0 = s.pool().free_pages();
        // Seq 0 prefills and publishes; 1 and 2 arrive while it still
        // runs and ride its pages.
        s.enqueue_shared(0, 64, 8, h.clone());
        for id in s.next_iteration().producers() {
            assert!(!s.advance(id));
        }
        let _ = s.next_iteration(); // publish tick
        assert!(!s.advance(0));
        s.enqueue_shared(1, 64, 2, h.clone());
        s.enqueue_shared(2, 64, 2, h);
        let (order, _) = run_to_completion(&mut s, 64);
        assert_eq!(order.len(), 3);
        assert!(s.prefix_hit_tokens() > 0, "later arrivals must hit the trie");
        assert_eq!(s.pool().in_use(), 0, "refcount leak");
        assert_eq!(s.pool().trie_len(), 0, "trie leak");
        assert_eq!(s.pool().free_pages(), free0, "free list must return to initial");
    }
}
