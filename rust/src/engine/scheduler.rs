//! Iteration-level scheduler: which sequences run in the next decode
//! iteration, against the paged KV pool.
//!
//! Each call to [`IterationScheduler::next_iteration`] is one engine
//! tick:
//!
//! 1. **grow** — every running sequence is about to produce one more
//!    token, so its context grows by one; pages for the growth are
//!    reserved oldest-first. On pool exhaustion the *newest* running
//!    sequence is preempted (vLLM's recompute policy: its pages are
//!    freed, its progress resets, and it re-queues at the *front* of
//!    the wait queue so FIFO order is preserved);
//! 2. **admit** — waiting sequences are admitted strictly FIFO while
//!    the pool has pages for their prompt-plus-first-token context and
//!    the running set is under `max_running`.
//!
//! The scheduler never deadlocks: when a sequence cannot fit even with
//! every other sequence preempted (the pool is smaller than one
//! request), the pool is force-expanded to hold it and the expansion is
//! counted — a misconfigured pool degrades with accounting instead of
//! wedging the engine. Completion bookkeeping ([`advance`]/[`retire`])
//! lives here too so the paged discrete-event simulator can drive the
//! *same* scheduler the live engine runs (see [`crate::sim::des`]).
//!
//! [`advance`]: IterationScheduler::advance
//! [`retire`]: IterationScheduler::retire

use std::collections::{HashMap, VecDeque};

use super::kv::{KvPool, SeqId};

/// Token bookkeeping of one tracked sequence.
#[derive(Debug, Clone, Copy)]
struct Seq {
    prompt_tokens: usize,
    max_new: usize,
    /// Tokens generated since (re-)admission; preemption resets this
    /// (recompute semantics).
    generated: usize,
}

/// One planned engine iteration.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    /// Sequences admitted this tick — they need a prefill pass and
    /// produce their first token.
    pub admitted: Vec<SeqId>,
    /// Sequences carried over from earlier ticks — they advance one
    /// decode token.
    pub decode: Vec<SeqId>,
    /// Sequences preempted this tick. Their KV pages are already freed
    /// and their progress reset; callers must drop any per-sequence
    /// backend state (they re-prefill on re-admission).
    pub preempted: Vec<SeqId>,
    /// Forced pool expansions this tick (0 unless the pool was smaller
    /// than a single sequence).
    pub forced_expansions: usize,
}

impl IterationPlan {
    /// Total sequences advancing one token this tick.
    pub fn batch(&self) -> usize {
        self.admitted.len() + self.decode.len()
    }
}

/// FIFO iteration scheduler over a paged KV pool.
#[derive(Debug)]
pub struct IterationScheduler {
    pool: KvPool,
    waiting: VecDeque<SeqId>,
    /// Admission order, oldest first.
    running: Vec<SeqId>,
    seqs: HashMap<SeqId, Seq>,
    max_running: usize,
    preemptions: u64,
    forced_expansions: u64,
}

impl IterationScheduler {
    /// `max_running` bounds the running set by request count on top of
    /// the pool's page bound (use `usize::MAX` for pages-only).
    pub fn new(pool: KvPool, max_running: usize) -> IterationScheduler {
        IterationScheduler {
            pool,
            waiting: VecDeque::new(),
            running: Vec::new(),
            seqs: HashMap::new(),
            max_running: max_running.max(1),
            preemptions: 0,
            forced_expansions: 0,
        }
    }

    /// Track a new sequence at the back of the wait queue.
    pub fn enqueue(&mut self, id: SeqId, prompt_tokens: usize, max_new: usize) {
        debug_assert!(!self.seqs.contains_key(&id), "duplicate sequence id");
        self.seqs.insert(
            id,
            Seq { prompt_tokens: prompt_tokens.max(1), max_new: max_new.max(1), generated: 0 },
        );
        self.waiting.push_back(id);
    }

    /// Waiting + running sequences.
    pub fn n_seqs(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.n_seqs() == 0
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Retarget the pool (hot-swap lever). Scale-down takes effect as
    /// sequences retire — see [`KvPool::resize`].
    pub fn resize_pool(&mut self, pages: usize) {
        self.pool.resize(pages);
    }

    pub fn max_running(&self) -> usize {
        self.max_running
    }

    pub fn set_max_running(&mut self, max_running: usize) {
        self.max_running = max_running.max(1);
    }

    /// Sequences preempted over the scheduler's lifetime.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Forced pool expansions over the scheduler's lifetime.
    pub fn forced_expansions(&self) -> u64 {
        self.forced_expansions
    }

    /// Tokens of context `id` currently holds KV for.
    fn ctx_tokens(&self, id: SeqId) -> usize {
        let s = &self.seqs[&id];
        s.prompt_tokens + s.generated
    }

    /// Preempt `id`: free its pages, reset its progress, and requeue it
    /// at the front of the wait queue.
    fn preempt(&mut self, id: SeqId, plan: &mut IterationPlan) {
        self.pool.release(id);
        if let Some(s) = self.seqs.get_mut(&id) {
            s.generated = 0;
        }
        self.waiting.push_front(id);
        plan.preempted.push(id);
        self.preemptions += 1;
    }

    /// Grow the pool just enough to cover a `short`-page shortfall even
    /// while over-committed (the no-deadlock escape hatch).
    fn force_expand(&mut self, short: usize, plan: &mut IterationPlan) {
        let want = (self.pool.in_use() + self.pool.free_pages() + short)
            .max(self.pool.capacity() + 1);
        self.pool.resize(want);
        self.forced_expansions += 1;
        plan.forced_expansions += 1;
    }

    /// Plan the next iteration. See the module docs for the policy.
    pub fn next_iteration(&mut self) -> IterationPlan {
        let mut plan = IterationPlan::default();

        // 1. Reserve one token of growth per running sequence, oldest
        // first; preempt from the newest end on exhaustion.
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let need = self.ctx_tokens(id) + 1;
            let mut preempted_self = false;
            while let Err(short) = self.pool.grow_to(id, need) {
                if self.running.len() == 1 {
                    // Alone and still short: the pool cannot hold even
                    // this one sequence.
                    self.force_expand(short.0, &mut plan);
                } else {
                    let victim = self.running.pop().expect("len > 1");
                    self.preempt(victim, &mut plan);
                    if victim == id {
                        preempted_self = true;
                        break;
                    }
                }
            }
            if !preempted_self {
                i += 1;
            }
        }

        // Survivors decode one token this tick.
        plan.decode = self.running.clone();

        // 2. Admit strictly FIFO while prompt+first-token contexts fit.
        while self.running.len() < self.max_running {
            let Some(&head) = self.waiting.front() else { break };
            let need = self.seqs[&head].prompt_tokens + 1;
            match self.pool.grow_to(head, need) {
                Ok(()) => {
                    self.waiting.pop_front();
                    self.running.push(head);
                    plan.admitted.push(head);
                }
                Err(short) => {
                    if self.running.is_empty() {
                        // Nothing running and the head alone does not
                        // fit: expand or the engine deadlocks.
                        self.force_expand(short.0, &mut plan);
                        continue;
                    }
                    break;
                }
            }
        }
        plan
    }

    /// Record one generated token for `id`; returns true when the
    /// sequence reached its token budget (caller should retire it).
    pub fn advance(&mut self, id: SeqId) -> bool {
        let s = self.seqs.get_mut(&id).expect("advance of unknown sequence");
        s.generated += 1;
        s.generated >= s.max_new
    }

    /// Drop a finished (or cancelled) sequence and free its pages.
    pub fn retire(&mut self, id: SeqId) {
        self.pool.release(id);
        if let Some(pos) = self.running.iter().position(|&r| r == id) {
            self.running.remove(pos);
        } else if let Some(pos) = self.waiting.iter().position(|&r| r == id) {
            let _ = self.waiting.remove(pos);
        }
        self.seqs.remove(&id);
    }

    /// Remove and return every tracked sequence (waiting first, then
    /// running, both FIFO), freeing all pages — the worker-death path.
    pub fn drain_ids(&mut self) -> Vec<SeqId> {
        let mut out: Vec<SeqId> = self.waiting.drain(..).collect();
        out.extend(self.running.drain(..));
        for &id in &out {
            self.pool.release(id);
        }
        self.seqs.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(pages: usize, page_tokens: usize, max_running: usize) -> IterationScheduler {
        IterationScheduler::new(KvPool::new(pages, page_tokens), max_running)
    }

    /// Drive the scheduler to completion, retiring sequences as they
    /// finish; returns (completion order, iterations used).
    fn run_to_completion(s: &mut IterationScheduler, max_iters: usize) -> (Vec<SeqId>, usize) {
        let mut order = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters <= max_iters, "scheduler failed to make progress");
            let plan = s.next_iteration();
            assert!(plan.batch() > 0, "a tick with sequences must advance something");
            let advanced: Vec<SeqId> =
                plan.admitted.iter().chain(&plan.decode).copied().collect();
            for id in advanced {
                if s.advance(id) {
                    s.retire(id);
                    order.push(id);
                }
            }
        }
        (order, iters)
    }

    #[test]
    fn admission_is_fifo() {
        let mut s = sched(64, 16, 4);
        for id in 0..6u64 {
            s.enqueue(id, 16, 4);
        }
        let plan = s.next_iteration();
        assert_eq!(plan.admitted, vec![0, 1, 2, 3], "max_running caps the batch");
        assert!(plan.decode.is_empty());
        let plan2 = s.next_iteration();
        assert_eq!(plan2.decode, vec![0, 1, 2, 3]);
        assert!(plan2.admitted.is_empty(), "running set is full");
    }

    #[test]
    fn completion_frees_room_for_the_queue() {
        let mut s = sched(64, 16, 2);
        for id in 0..4u64 {
            s.enqueue(id, 8, 2);
        }
        let (order, _) = run_to_completion(&mut s, 64);
        assert_eq!(order, vec![0, 1, 2, 3], "FIFO completion under a count bound");
        assert_eq!(s.pool().in_use(), 0, "all pages returned");
        assert_eq!(s.preemptions(), 0);
    }

    #[test]
    fn pool_exhaustion_preempts_newest_and_requeues_front() {
        // 4 pages of 16 tokens; each seq needs 2 pages at admission
        // (prompt 17 -> 2 pages) and grows into a 3rd page later
        // (17 + 16 = 33 tokens -> 3 pages at generated = 16).
        let mut s = sched(4, 16, 8);
        s.enqueue(0, 17, 20);
        s.enqueue(1, 17, 20);
        let first = s.next_iteration();
        assert_eq!(first.admitted, vec![0, 1]);
        // Tick until growth forces a preemption: seq 1 (newest) must be
        // the victim, exactly once, and re-admit after 0 retires.
        let mut preempted_events: Vec<SeqId> = Vec::new();
        let mut done: Vec<SeqId> = Vec::new();
        let mut iters = 0;
        // Consume the first tick's tokens.
        for id in first.admitted {
            assert!(!s.advance(id));
        }
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 200, "no deadlock allowed");
            let plan = s.next_iteration();
            preempted_events.extend(&plan.preempted);
            assert!(plan.batch() > 0);
            for id in plan.admitted.iter().chain(&plan.decode).copied().collect::<Vec<_>>() {
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1], "both sequences complete, oldest first");
        assert!(!preempted_events.is_empty(), "the tight pool must preempt");
        assert!(
            preempted_events.iter().all(|&id| id == 1),
            "only the newest sequence may be preempted: {preempted_events:?}"
        );
        assert_eq!(s.forced_expansions(), 0, "a sane pool never force-expands");
        assert!(s.pool().peak_in_use() <= 4, "occupancy may never exceed the pool");
    }

    #[test]
    fn many_sequences_tiny_pool_never_deadlocks() {
        let mut s = sched(6, 8, 64);
        for id in 0..12u64 {
            s.enqueue(id, 12, 24); // worst case 12+24 = 36 tokens = 5 pages
        }
        let (order, _) = run_to_completion(&mut s, 5_000);
        assert_eq!(order.len(), 12);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 12, "exactly-once completion");
        assert_eq!(s.forced_expansions(), 0);
        assert!(s.pool().peak_in_use() <= 6);
    }

    #[test]
    fn oversized_sequence_forces_expansion_instead_of_deadlock() {
        // Pool of 2 pages cannot hold a 100-token prompt (7 pages).
        let mut s = sched(2, 16, 4);
        s.enqueue(0, 100, 4);
        let (order, _) = run_to_completion(&mut s, 32);
        assert_eq!(order, vec![0]);
        assert!(s.forced_expansions() >= 1, "expansion must be accounted");
    }

    #[test]
    fn preempted_sequence_restarts_from_scratch() {
        let mut s = sched(4, 16, 8);
        s.enqueue(0, 17, 40);
        s.enqueue(1, 17, 40);
        let mut total_advances_for_1 = 0usize;
        let mut saw_preempt = false;
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 500);
            let plan = s.next_iteration();
            if plan.preempted.contains(&1) {
                saw_preempt = true;
            }
            for id in plan.admitted.iter().chain(&plan.decode).copied().collect::<Vec<_>>() {
                if id == 1 {
                    total_advances_for_1 += 1;
                }
                if s.advance(id) {
                    s.retire(id);
                }
            }
        }
        assert!(saw_preempt);
        assert!(
            total_advances_for_1 > 40,
            "recompute must replay preempted progress ({total_advances_for_1} advances)"
        );
    }

    #[test]
    fn resize_down_blocks_admission_until_drain() {
        let mut s = sched(8, 16, 8);
        s.enqueue(0, 30, 4); // 2 pages minimum
        let plan = s.next_iteration();
        assert_eq!(plan.admitted, vec![0]);
        s.resize_pool(1); // below the running seq's footprint
        s.enqueue(1, 30, 4);
        // Seq 1 cannot be admitted while 0 holds the over-committed
        // pool, but 0 still runs (forced expansion only grows to cover
        // growth of the lone running seq).
        let plan2 = s.next_iteration();
        assert_eq!(plan2.decode, vec![0]);
        assert!(plan2.admitted.is_empty());
        (0..4).for_each(|_| {
            if s.advance(0) {
                s.retire(0);
            }
        });
        assert!(!s.running.contains(&0));
        // With 0 gone the pool drains; seq 1 admits (forced expansion
        // may fire because 1 page < one sequence).
        let plan3 = s.next_iteration();
        assert_eq!(plan3.admitted, vec![1]);
    }

    #[test]
    fn drain_returns_everything_and_frees_pages() {
        let mut s = sched(16, 16, 2);
        for id in 0..5u64 {
            s.enqueue(id, 16, 4);
        }
        let _ = s.next_iteration(); // admit 0, 1
        let ids = s.drain_ids();
        assert_eq!(ids.len(), 5);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        assert_eq!(s.pool().in_use(), 0);
        assert!(s.is_idle());
    }
}
