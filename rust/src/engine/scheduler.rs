//! Iteration-level scheduler: which sequences run in the next decode
//! iteration, against the paged KV pool.
//!
//! Each call to [`IterationScheduler::next_iteration`] is one engine
//! tick:
//!
//! 1. **publish** — sequences whose prefill completed in an earlier
//!    tick publish their prompt pages into the pool's prefix trie
//!    ([`KvPool::publish_prefix`]), so later admissions with the same
//!    prompt prefix can claim them;
//! 2. **grow** — every decoding sequence is about to produce one more
//!    token, so its context grows by one; pages for the growth are
//!    reserved oldest-first. On pool exhaustion the *newest* running
//!    sequence is evicted, per victim choosing between two disciplines
//!    ([`PreemptionConfig`]): *recompute* (vLLM's default: pages
//!    freed, progress — including partial prefill — resets, requeue at
//!    the front of the wait queue) and *swap-to-host* (park the
//!    victim's private pages in the pool's host swap space; generated
//!    tokens and completed prefill chunks are checkpointed and survive
//!    — see [`KvPool::swap_out`]). The choice compares the recompute
//!    cost (resident tokens × prefill rate) against the PCIe round
//!    trip (2 × private pages × per-page swap time) and falls back to
//!    recompute when the host budget is full;
//! 2½. **resume** — sequences parked in swap space re-enter *ahead of
//!    new admissions* (FIFO among themselves) as device pages free up:
//!    a resumed decoder decodes this very tick, a resumed partial
//!    prefill continues from its checkpointed chunk instead of
//!    restarting at token 0;
//! 3. **prefill** — sequences still prefilling get the next chunk of
//!    their prompt, oldest first, under the per-tick token budget
//!    (`prefill_chunk`, Sarathi-style): long prompts are spread over
//!    several iterations interleaved with decode instead of charging
//!    the whole prompt into one admission tick. The chunk that
//!    completes a prompt also produces the first token;
//! 4. **admit** — waiting sequences are admitted strictly FIFO while
//!    the pool has pages and the running set is under `max_running`.
//!    Admission first walks the prefix trie ([`KvPool::claim_prefix`]):
//!    claimed tokens need neither pages nor prefill compute, and a
//!    full-prompt hit (a cascade re-serve, a same-prompt retry) skips
//!    prefill entirely and decodes its first token this very tick.
//!
//! The scheduler never deadlocks: when a sequence cannot fit even with
//! every other sequence preempted (the pool is smaller than one
//! request), the pool is force-expanded to hold it and the expansion is
//! counted — a misconfigured pool degrades with accounting instead of
//! wedging the engine. Completion bookkeeping ([`advance`]/[`retire`])
//! lives here too so the paged discrete-event simulator can drive the
//! *same* scheduler the live engine runs (see [`crate::sim::des`]).
//!
//! [`advance`]: IterationScheduler::advance
//! [`retire`]: IterationScheduler::retire

// BTreeMap, not HashMap: this scheduler is replayed by the DES
// equivalence pins, so every keyed structure must iterate in a
// deterministic order (the `determinism` lint enforces this).
use std::collections::{BTreeMap, VecDeque};

use super::kv::{KvPool, SeqId};

/// How the scheduler evicts sequences on pool exhaustion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptionMode {
    /// Free the victim's pages; it re-prefills from token 0 on
    /// re-admission (vLLM's recompute default).
    #[default]
    Recompute,
    /// Swap-to-host allowed: per victim, park its KV in host swap
    /// space when the PCIe round trip is cheaper than re-prefilling
    /// its resident context (falling back to recompute when the swap
    /// budget is exhausted). Swapped progress — generated tokens AND
    /// completed prefill chunks — survives the preemption.
    Swap,
}

/// Role of an engine (and its scheduler) inside a tier's worker pool.
/// Unified is the only mode that existed before the prefill/decode
/// split; the two split roles are what a `disagg`-annotated tier's
/// plan deploys ([`crate::sched::DisaggSpec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineRole {
    /// Serve both phases from one pool.
    #[default]
    Unified,
    /// Chunked prefill only: a sequence that completes its prompt
    /// (and produces its first token) hands off to a decode worker at
    /// the next tick — its private KV pages migrate over the
    /// interconnect ([`IterationPlan::migrated_out`]). When migration
    /// is closed (no live decode worker, transfer budget exhausted)
    /// the sequence simply keeps decoding locally: the pool degrades
    /// to unified serving instead of wedging.
    Prefill,
    /// Decode only: admits prefilled sequences migrated from peer
    /// prefill workers ([`IterationScheduler::enqueue_prefilled`]);
    /// shared prefix pages are re-claimed from the local trie rather
    /// than moved.
    Decode,
}

/// Preemption policy plus the cost terms its per-victim choice
/// compares (derive them from a [`crate::perf::ReplicaModel`] via
/// [`crate::engine::EngineConfig`]; zeros make Swap mode always prefer
/// the swap path while budget remains).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PreemptionConfig {
    pub mode: PreemptionMode,
    /// Host swap budget in pages (0 disables swap even in Swap mode).
    pub swap_pages: usize,
    /// Seconds to re-establish one token of context by recompute.
    pub prefill_s_per_token: f64,
    /// Seconds to move one KV page across PCIe, one direction.
    pub swap_s_per_page: f64,
    /// Bytes one KV page occupies (telemetry: swap_bytes reporting).
    pub page_bytes: f64,
}

/// Token bookkeeping of one tracked sequence.
#[derive(Debug, Clone)]
struct Seq {
    prompt_tokens: usize,
    max_new: usize,
    /// Tokens generated since (re-)admission; preemption resets this
    /// (recompute semantics).
    generated: usize,
    /// Prompt tokens whose KV is resident (claimed prefix + prefill
    /// chunks done); preemption resets this too.
    prefilled: usize,
    /// Prompt pages published into the prefix trie (or inherited via a
    /// full claim).
    published: bool,
    /// Pinned to this worker: set on migrated-in sequences (they
    /// already crossed the interconnect once) so a Prefill-role
    /// scheduler that had to keep a handoff local never re-offers it.
    decode_local: bool,
    /// Chained page hashes of the prompt (empty = sharing disabled).
    hashes: Vec<u64>,
}

impl Seq {
    fn decoding(&self) -> bool {
        self.prefilled >= self.prompt_tokens
    }
}

/// One prefill chunk scheduled into an iteration: process prompt
/// tokens `start .. start + len` of sequence `id`. `last` marks the
/// chunk that completes the prompt — it produces the first token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkTask {
    pub id: SeqId,
    pub start: usize,
    pub len: usize,
    pub last: bool,
}

/// One speculative draft→verify pair scheduled into an iteration:
/// draft `k` tokens for `id` past its verified context, then verify
/// them in one deep-model step. The sequence emits between 1 and
/// `k + 1` tokens this tick (accepted prefix + the verifier's own next
/// token); rejected slack pages roll back at
/// [`IterationScheduler::advance_spec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecTask {
    pub id: SeqId,
    pub k: usize,
}

/// One planned engine iteration.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    /// Sequences newly admitted this tick that owe prefill work (their
    /// first chunk is in `prefill`). Full-prefix-hit admissions appear
    /// in `decode` instead — their KV is already resident.
    pub admitted: Vec<SeqId>,
    /// Prefill chunks to process this tick (newly admitted sequences'
    /// first chunks and carried-over partial prefills). A `last` chunk
    /// produces the sequence's first token.
    pub prefill: Vec<ChunkTask>,
    /// Fully-prefilled sequences advancing one decode token.
    /// Sequences with a speculative task this tick appear in `spec`
    /// instead, never here.
    pub decode: Vec<SeqId>,
    /// Speculative draft→verify pairs this tick (empty unless
    /// [`IterationScheduler::set_spec_k`] enabled speculation). Each
    /// sequence already holds pages for its verified context plus one
    /// growth token plus `k` draft slack tokens.
    pub spec: Vec<SpecTask>,
    /// Sequences preempted-with-recompute this tick. Their KV pages are
    /// already freed and their progress (decode *and* partial prefill)
    /// reset; callers must drop any per-sequence backend state (they
    /// re-prefill on re-admission). Swap-evicted victims appear in
    /// `swapped_out` instead — their state survives.
    pub preempted: Vec<SeqId>,
    /// Sequences swapped out to host this tick, with the page count
    /// each moved across PCIe. Their progress — generated tokens and
    /// completed prefill chunks — is checkpointed; callers must KEEP
    /// per-sequence backend state (they resume, not recompute).
    pub swapped_out: Vec<(SeqId, usize)>,
    /// Sequences resumed from host swap this tick, with the page count
    /// each moved back. Resumed decoders decode this very tick;
    /// resumed partial prefills continue at their checkpoint.
    pub swapped_in: Vec<(SeqId, usize)>,
    /// Sequences handed off to a decode worker this tick (Prefill role
    /// only), with the count of private pages each sends over the
    /// interconnect. Their pages and bookkeeping are already gone from
    /// this scheduler; the caller owns routing them to a peer
    /// ([`IterationScheduler::enqueue_prefilled`] on the destination).
    pub migrated_out: Vec<(SeqId, usize)>,
    /// Migrated sequences admitted this tick, with the private pages
    /// each actually pulled over the interconnect (shared prefix pages
    /// were re-claimed from the local trie instead of moving). They
    /// decode this very tick.
    pub migrated_in: Vec<(SeqId, usize)>,
    /// Forced pool expansions this tick (0 unless the pool was smaller
    /// than a single sequence).
    pub forced_expansions: usize,
}

impl IterationPlan {
    /// Total sequences occupying a batch slot this tick (decoding,
    /// prefilling, or speculating).
    pub fn batch(&self) -> usize {
        self.prefill.len() + self.decode.len() + self.spec.len()
    }

    /// Prompt tokens of prefill work charged into this tick.
    pub fn prefill_tokens(&self) -> usize {
        self.prefill.iter().map(|c| c.len).sum()
    }

    /// Sequences producing exactly one token this tick: every decoder
    /// plus every sequence whose *last* prefill chunk lands here.
    /// Speculative tasks are NOT listed — they produce a variable
    /// token count settled at [`IterationScheduler::advance_spec`].
    pub fn producers(&self) -> Vec<SeqId> {
        let mut v: Vec<SeqId> = self.decode.clone();
        v.extend(self.prefill.iter().filter(|c| c.last).map(|c| c.id));
        v
    }

    /// KV pages moved to host this tick.
    pub fn swap_out_pages(&self) -> usize {
        self.swapped_out.iter().map(|&(_, p)| p).sum()
    }

    /// KV pages moved back from host this tick.
    pub fn swap_in_pages(&self) -> usize {
        self.swapped_in.iter().map(|&(_, p)| p).sum()
    }

    /// KV pages sent to peer decode workers this tick.
    pub fn migrate_out_pages(&self) -> usize {
        self.migrated_out.iter().map(|&(_, p)| p).sum()
    }

    /// KV pages received from peer prefill workers this tick.
    pub fn migrate_in_pages(&self) -> usize {
        self.migrated_in.iter().map(|&(_, p)| p).sum()
    }
}

/// Scheduler invariant: every id in `waiting`/`running`/`swapped_q` has
/// a live `seqs` entry (they are inserted together at submit and removed
/// together at retire). A miss means the queues and the sequence table
/// diverged — panic with the id and phase instead of planning a bogus
/// iteration.
fn known<V>(entry: Option<V>, id: SeqId, phase: &str) -> V {
    match entry {
        Some(v) => v,
        None => panic!("scheduler invariant violated: {phase} of unknown sequence {id}"),
    }
}

/// FIFO iteration scheduler over a paged KV pool.
#[derive(Debug)]
pub struct IterationScheduler {
    pool: KvPool,
    waiting: VecDeque<SeqId>,
    /// Admission order, oldest first.
    running: Vec<SeqId>,
    /// Sequences parked in host swap space, oldest eviction first;
    /// they resume ahead of new admissions.
    swapped_q: VecDeque<SeqId>,
    /// Prefilled sequences migrated from a peer prefill worker, FIFO;
    /// they admit ahead of fresh arrivals (their prefill compute is
    /// already spent) and behind swap resumes.
    migrate_q: VecDeque<SeqId>,
    seqs: BTreeMap<SeqId, Seq>,
    max_running: usize,
    /// Prefill token budget per iteration (`usize::MAX` = whole-prompt
    /// admission, the pre-chunking discipline).
    prefill_chunk: usize,
    preemption: PreemptionConfig,
    role: EngineRole,
    /// Whether a Prefill-role scheduler may hand sequences off this
    /// tick (the caller gates it on live decode capacity); closed,
    /// finished prefills keep decoding locally — unified degradation.
    migration_open: bool,
    /// Draft tokens per speculative task (0 = speculation off).
    spec_k: usize,
    preemptions: u64,
    forced_expansions: u64,
    prefix_hit_tokens: u64,
    migrations_out: u64,
    migrations_in: u64,
    migrate_pages_out: u64,
    migrate_pages_in: u64,
    spec_accepted: u64,
    spec_rejected: u64,
}

impl IterationScheduler {
    /// `max_running` bounds the running set by request count on top of
    /// the pool's page bound (use `usize::MAX` for pages-only).
    pub fn new(pool: KvPool, max_running: usize) -> IterationScheduler {
        IterationScheduler {
            pool,
            waiting: VecDeque::new(),
            running: Vec::new(),
            swapped_q: VecDeque::new(),
            migrate_q: VecDeque::new(),
            seqs: BTreeMap::new(),
            max_running: max_running.max(1),
            prefill_chunk: usize::MAX,
            preemption: PreemptionConfig::default(),
            role: EngineRole::Unified,
            migration_open: false,
            spec_k: 0,
            preemptions: 0,
            forced_expansions: 0,
            prefix_hit_tokens: 0,
            migrations_out: 0,
            migrations_in: 0,
            migrate_pages_out: 0,
            migrate_pages_in: 0,
            spec_accepted: 0,
            spec_rejected: 0,
        }
    }

    /// Assign this scheduler's role in a disaggregated tier. A Prefill
    /// scheduler starts with migration open (the caller may close it
    /// per tick via [`IterationScheduler::set_migration_open`]).
    pub fn set_role(&mut self, role: EngineRole) {
        self.role = role;
        self.migration_open = role == EngineRole::Prefill;
    }

    pub fn role(&self) -> EngineRole {
        self.role
    }

    /// Gate this tick's prefill→decode handoffs: closed, sequences that
    /// finished prefill decode locally instead (unified degradation).
    /// No effect outside the Prefill role.
    pub fn set_migration_open(&mut self, open: bool) {
        self.migration_open = self.role == EngineRole::Prefill && open;
    }

    /// Select the eviction policy and its cost terms. Swap mode sizes
    /// the pool's host swap space from the config's page budget.
    pub fn set_preemption(&mut self, cfg: PreemptionConfig) {
        self.preemption = cfg;
        self.pool.set_swap_capacity(match cfg.mode {
            PreemptionMode::Swap => cfg.swap_pages,
            PreemptionMode::Recompute => 0,
        });
    }

    pub fn preemption(&self) -> PreemptionConfig {
        self.preemption
    }

    /// Cap the prefill tokens charged into any one iteration (clamped
    /// to at least one page so every prefilling sequence can progress).
    pub fn set_prefill_chunk(&mut self, tokens: usize) {
        self.prefill_chunk = tokens.max(self.pool.page_tokens());
    }

    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Enable speculative draft→verify planning with `k` draft tokens
    /// per task (0 disables it). Takes effect at the next
    /// [`IterationScheduler::next_iteration`]; drafts never span ticks,
    /// so flipping this mid-run strands no draft state.
    pub fn set_spec_k(&mut self, k: usize) {
        self.spec_k = k;
    }

    pub fn spec_k(&self) -> usize {
        self.spec_k
    }

    /// Lifetime (accepted, rejected) draft-token counts settled through
    /// [`IterationScheduler::advance_spec`].
    pub fn spec_counts(&self) -> (u64, u64) {
        (self.spec_accepted, self.spec_rejected)
    }

    /// Track a new sequence at the back of the wait queue.
    pub fn enqueue(&mut self, id: SeqId, prompt_tokens: usize, max_new: usize) {
        self.enqueue_shared(id, prompt_tokens, max_new, Vec::new());
    }

    /// Like [`IterationScheduler::enqueue`], with the prompt's chained
    /// page hashes ([`crate::engine::prompt_page_hashes`], computed at
    /// the pool's page size): admission will claim any published
    /// prefix and publish the prompt's pages once prefilled.
    pub fn enqueue_shared(
        &mut self,
        id: SeqId,
        prompt_tokens: usize,
        max_new: usize,
        hashes: Vec<u64>,
    ) {
        debug_assert!(!self.seqs.contains_key(&id), "duplicate sequence id");
        self.seqs.insert(
            id,
            Seq {
                prompt_tokens: prompt_tokens.max(1),
                max_new: max_new.max(1),
                generated: 0,
                prefilled: 0,
                published: false,
                decode_local: false,
                hashes,
            },
        );
        self.waiting.push_back(id);
    }

    /// Track a sequence whose prefill already ran on a peer prefill
    /// worker (the migration path): its whole prompt counts as
    /// prefilled, `generated` carries the tokens produced so far (the
    /// prefill side's first token at least), and it queues for
    /// admission ahead of fresh arrivals. At admission the pool claims
    /// any locally published prefix first — only the unclaimed private
    /// remainder is accounted as pages pulled over the interconnect
    /// ([`IterationPlan::migrated_in`]).
    pub fn enqueue_prefilled(
        &mut self,
        id: SeqId,
        prompt_tokens: usize,
        generated: usize,
        max_new: usize,
        hashes: Vec<u64>,
    ) {
        debug_assert!(!self.seqs.contains_key(&id), "duplicate sequence id");
        let prompt_tokens = prompt_tokens.max(1);
        self.seqs.insert(
            id,
            Seq {
                prompt_tokens,
                max_new: max_new.max(1),
                generated,
                prefilled: prompt_tokens,
                published: false,
                decode_local: true,
                hashes,
            },
        );
        self.migrate_q.push_back(id);
    }

    /// Waiting + running + swapped + migration-queued sequences.
    pub fn n_seqs(&self) -> usize {
        self.waiting.len() + self.running.len() + self.swapped_q.len() + self.migrate_q.len()
    }

    /// Migrated-in sequences still waiting for admission.
    pub fn n_migrate_queued(&self) -> usize {
        self.migrate_q.len()
    }

    /// Sequences currently parked in host swap space.
    pub fn n_swapped(&self) -> usize {
        self.swapped_q.len()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.n_seqs() == 0
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Retarget the pool (hot-swap lever). Scale-down takes effect as
    /// sequences retire — see [`KvPool::resize`].
    pub fn resize_pool(&mut self, pages: usize) {
        self.pool.resize(pages);
    }

    pub fn max_running(&self) -> usize {
        self.max_running
    }

    pub fn set_max_running(&mut self, max_running: usize) {
        self.max_running = max_running.max(1);
    }

    /// Sequences preempted-with-recompute over the scheduler's
    /// lifetime (swap evictions are counted separately — see
    /// [`IterationScheduler::swap_counts`]).
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Lifetime (swap-outs, swap-ins, pages moved across PCIe both
    /// directions) of the swap-to-host policy.
    pub fn swap_counts(&self) -> (u64, u64, u64) {
        self.pool.swap_counts()
    }

    /// Forced pool expansions over the scheduler's lifetime.
    pub fn forced_expansions(&self) -> u64 {
        self.forced_expansions
    }

    /// Prompt tokens served from shared prefix pages instead of being
    /// re-prefilled, over the scheduler's lifetime.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// Lifetime (handed off, admitted, pages sent, pages received) of
    /// the prefill→decode migration path.
    pub fn migrate_counts(&self) -> (u64, u64, u64, u64) {
        (self.migrations_out, self.migrations_in, self.migrate_pages_out, self.migrate_pages_in)
    }

    /// Preempt `id` with recompute: free its pages, reset its progress
    /// (decode and partial prefill), and requeue it at the front of the
    /// wait queue. Work already planned for the victim THIS tick is
    /// withdrawn — a later reservation may evict a sequence that
    /// entered the decode or chunk lists earlier in the same planning
    /// pass.
    fn preempt(&mut self, id: SeqId, plan: &mut IterationPlan) {
        self.pool.release(id);
        if let Some(s) = self.seqs.get_mut(&id) {
            s.generated = 0;
            s.prefilled = 0;
            s.published = false;
        }
        self.waiting.push_front(id);
        plan.decode.retain(|&d| d != id);
        plan.prefill.retain(|c| c.id != id);
        plan.spec.retain(|t| t.id != id);
        plan.preempted.push(id);
        self.preemptions += 1;
    }

    /// Whether the per-victim cost model picks swap over recompute for
    /// `id`: the policy allows it, the host budget holds the victim's
    /// private pages, and the PCIe round trip is no dearer than
    /// re-prefilling its resident context.
    fn should_swap(&self, id: SeqId) -> bool {
        if self.preemption.mode != PreemptionMode::Swap {
            return false;
        }
        let Some(s) = self.seqs.get(&id) else { return false };
        let (_, owned) = self.pool.swap_split(id);
        if owned > self.pool.swap_free() {
            return false;
        }
        // Recompute replays the whole resident context (prompt prefill
        // AND regenerated decode tokens) through the prefill path; swap
        // pays two PCIe moves per private page.
        let resident_tokens = (s.prefilled + s.generated) as f64;
        let recompute_cost = resident_tokens * self.preemption.prefill_s_per_token;
        let swap_cost = 2.0 * owned as f64 * self.preemption.swap_s_per_page;
        swap_cost <= recompute_cost
    }

    /// Swap `id` out to host: its progress (decode and completed
    /// prefill chunks — the chunk checkpoint) survives; it re-enters
    /// through the swap queue ahead of new admissions. Falls back to
    /// recompute-preemption if the host budget races out.
    fn swap_out_victim(&mut self, id: SeqId, plan: &mut IterationPlan) {
        match self.pool.swap_out(id) {
            Ok(pages) => {
                self.swapped_q.push_back(id);
                plan.decode.retain(|&d| d != id);
                plan.prefill.retain(|c| c.id != id);
                plan.spec.retain(|t| t.id != id);
                plan.swapped_out.push((id, pages));
            }
            Err(_) => self.preempt(id, plan),
        }
    }

    /// Evict `victim` to relieve pool pressure, choosing per victim
    /// between swap-to-host and preempt-with-recompute by the
    /// configured cost terms. A victim holding a speculative task this
    /// tick first withdraws its draft: the unverified slack pages roll
    /// back so it parks (or resets) at its last *verified* token — the
    /// swap cost model and the parked checkpoint never see draft state.
    fn evict(&mut self, victim: SeqId, plan: &mut IterationPlan) {
        if plan.spec.iter().any(|t| t.id == victim) {
            if let Some(s) = self.seqs.get(&victim) {
                self.pool.rollback_to(victim, s.prompt_tokens + s.generated + 1);
            }
            plan.spec.retain(|t| t.id != victim);
        }
        if self.should_swap(victim) {
            self.swap_out_victim(victim, plan);
        } else {
            self.preempt(victim, plan);
        }
    }

    /// Grow the pool just enough to cover a `short`-page shortfall even
    /// while over-committed (the no-deadlock escape hatch).
    fn force_expand(&mut self, short: usize, plan: &mut IterationPlan) {
        let want = (self.pool.in_use() + self.pool.free_pages() + short)
            .max(self.pool.capacity() + 1);
        self.pool.resize(want);
        self.forced_expansions += 1;
        plan.forced_expansions += 1;
    }

    /// Reserve pages so `id`'s context covers `tokens`, preempting the
    /// newest running sequence on exhaustion (or force-expanding when
    /// `id` runs alone). Returns false iff `id` preempted itself.
    fn reserve(&mut self, id: SeqId, tokens: usize, plan: &mut IterationPlan) -> bool {
        while let Err(short) = self.pool.grow_to(id, tokens) {
            if self.running.len() <= 1 {
                // Alone and still short: the pool cannot hold even
                // this one sequence.
                self.force_expand(short.0, plan);
            } else {
                let Some(victim) = self.running.pop() else {
                    unreachable!("running.len() > 1 checked above")
                };
                self.evict(victim, plan);
                if victim == id {
                    return false;
                }
            }
        }
        true
    }

    /// Plan the next iteration. See the module docs for the policy.
    pub fn next_iteration(&mut self) -> IterationPlan {
        let mut plan = IterationPlan::default();

        // -1. Prefill-role handoff: sequences whose prefill completed
        // last tick (they produced their first token there) leave for a
        // decode worker instead of decoding here. Their pages are
        // released now — only the private (unshared) count crosses the
        // interconnect; the decode side re-claims shared prefix pages
        // from its own trie. With migration closed, or for sequences
        // pinned local (`decode_local`), this stage is a no-op and the
        // sequence decodes below exactly as a unified pool would.
        if self.role == EngineRole::Prefill && self.migration_open {
            let ready: Vec<SeqId> = self
                .running
                .iter()
                .copied()
                .filter(|id| {
                    let s = &self.seqs[id];
                    s.decoding() && s.generated <= 1 && !s.decode_local
                })
                .collect();
            for id in ready {
                let (_, owned) = self.pool.swap_split(id);
                self.pool.release(id);
                self.running.retain(|&r| r != id);
                self.seqs.remove(&id);
                plan.migrated_out.push((id, owned));
                self.migrations_out += 1;
                self.migrate_pages_out += owned as u64;
            }
        }

        // 0. Publish prompt pages of sequences whose prefill completed
        // in an earlier tick (their KV is computed by now).
        let publishable: Vec<SeqId> = self
            .running
            .iter()
            .copied()
            .filter(|id| {
                let s = &self.seqs[id];
                s.decoding() && !s.published
            })
            .collect();
        for id in publishable {
            let hashes = self.seqs[&id].hashes.clone();
            if !hashes.is_empty() {
                self.pool.publish_prefix(id, &hashes);
            }
            known(self.seqs.get_mut(&id), id, "publish").published = true;
        }

        // 1. Reserve one token of growth per decoding sequence, oldest
        // first; preempt from the newest end on exhaustion. With
        // speculation on, a steady decoder additionally tries to
        // reserve `k` draft-slack tokens — opportunistically, never by
        // evicting a peer, so pool pressure degrades speculation to
        // plain decode deterministically. The draft budget is capped so
        // even a fully accepted verify step (k + 1 tokens) cannot
        // overshoot `max_new`.
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let s = &self.seqs[&id];
            if !s.decoding() {
                i += 1;
                continue;
            }
            let need = s.prompt_tokens + s.generated + 1;
            let k_eff = self.spec_k.min(s.max_new.saturating_sub(s.generated + 1));
            if self.reserve(id, need, &mut plan) {
                if k_eff > 0 && self.pool.grow_by(id, k_eff).is_ok() {
                    plan.spec.push(SpecTask { id, k: k_eff });
                }
                i += 1;
            }
        }

        // 1.5. Resume swapped sequences AHEAD of new admissions (FIFO
        // among themselves): a resumed decoder also reserves this
        // tick's one-token growth so it decodes immediately, and a
        // resumed partial prefill continues at its checkpoint. A head
        // is resumed only when the pool holds its host pages PLUS its
        // next growth ([`KvPool::swap_in_headroom`]) — swapping a
        // victim in just to have its own reservation re-evict it would
        // thrash PCIe with zero progress. A head that cannot fit yet
        // stays parked and keeps its priority; if nothing is running
        // the pool force-expands rather than deadlocking against a
        // parked sequence.
        while let Some(&head) = self.swapped_q.front() {
            if self.running.len() >= self.max_running {
                break;
            }
            let s = &self.seqs[&head];
            let need_tokens = if s.decoding() {
                s.prompt_tokens + s.generated + 1
            } else {
                let remaining = s.prompt_tokens - s.prefilled;
                let len = remaining.min(self.prefill_chunk);
                s.prefilled + len + usize::from(len == remaining)
            };
            let headroom = self.pool.swap_in_headroom(head, need_tokens);
            if self.pool.free_pages() < headroom {
                if self.running.is_empty() {
                    self.force_expand(headroom - self.pool.free_pages(), &mut plan);
                    continue;
                }
                break;
            }
            match self.pool.swap_in(head) {
                Ok(pages) => {
                    self.swapped_q.pop_front();
                    self.running.push(head);
                    plan.swapped_in.push((head, pages));
                    let s = &self.seqs[&head];
                    if s.decoding() {
                        let need = s.prompt_tokens + s.generated + 1;
                        if !self.reserve(head, need, &mut plan) {
                            // The head evicted ITSELF reserving its
                            // decode growth (CoW pressure beyond the
                            // headroom margin): it re-parked (or
                            // reset). Stop resuming — retrying this
                            // tick would spin on the same shortfall.
                            break;
                        }
                    }
                }
                Err(short) => {
                    if self.running.is_empty() {
                        self.force_expand(short.0, &mut plan);
                        continue;
                    }
                    break;
                }
            }
        }

        // 1.75. Admit migrated-in sequences (prefill already done on a
        // peer worker), FIFO, after swap resumes and ahead of fresh
        // arrivals. Admission claims any locally published prefix
        // first, so only the private remainder is charged as
        // interconnect transfer; the sequence decodes this very tick.
        // Like swap resumes, a head that cannot fit stays queued
        // (never evicts a runner) unless nothing runs at all.
        while let Some(&head) = self.migrate_q.front() {
            if self.running.len() >= self.max_running {
                break;
            }
            let s = &self.seqs[&head];
            let prompt_tokens = s.prompt_tokens;
            let need = s.prompt_tokens + s.generated + 1;
            let hashes = s.hashes.clone();
            if !hashes.is_empty() && !self.pool.holds(head) {
                // Shared-prefix re-claim: pages the local trie already
                // holds never cross the interconnect.
                self.pool.claim_prefix(head, &hashes, prompt_tokens);
            }
            match self.pool.grow_to(head, need) {
                Ok(()) => {
                    self.migrate_q.pop_front();
                    self.running.push(head);
                    let (_, owned) = self.pool.swap_split(head);
                    plan.migrated_in.push((head, owned));
                    self.migrations_in += 1;
                    self.migrate_pages_in += owned as u64;
                }
                Err(short) => {
                    self.pool.retract_claim(head);
                    if self.running.is_empty() {
                        self.force_expand(short.0, &mut plan);
                        continue;
                    }
                    break;
                }
            }
        }

        // Surviving decoders advance one token this tick. Sequences
        // with a surviving speculative task advance through `spec`
        // instead; sequences that (re-)entered after stage 1 (swap
        // resume, migration admit, full prefix hit) decode plainly this
        // tick and become speculation candidates next tick.
        plan.decode = self
            .running
            .iter()
            .copied()
            .filter(|id| {
                self.seqs[id].decoding() && !plan.spec.iter().any(|t| t.id == *id)
            })
            .collect();

        // 2. Prefill chunks for carried-over partial prefills, oldest
        // first, under the tick's token budget.
        let mut budget = self.prefill_chunk;
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i];
            let s = &self.seqs[&id];
            if s.decoding() {
                i += 1;
                continue;
            }
            if budget == 0 {
                break;
            }
            let remaining = s.prompt_tokens - s.prefilled;
            let len = remaining.min(budget);
            let last = len == remaining;
            let start = s.prefilled;
            let need = start + len + usize::from(last);
            if self.reserve(id, need, &mut plan) {
                known(self.seqs.get_mut(&id), id, "prefill").prefilled = start + len;
                plan.prefill.push(ChunkTask { id, start, len, last });
                budget -= len;
                i += 1;
            }
        }

        // 3. Admit strictly FIFO while prefix-claimed-plus-first-chunk
        // contexts fit and budget remains. Parked sequences outrank the
        // wait queue: while any is still waiting to resume (from host
        // swap or a pending migration), admissions hold off so fresh
        // arrivals cannot starve checkpointed work.
        while self.running.len() < self.max_running
            && self.swapped_q.is_empty()
            && self.migrate_q.is_empty()
        {
            let Some(&head) = self.waiting.front() else { break };
            let prompt_tokens = self.seqs[&head].prompt_tokens;
            let claimed = if self.seqs[&head].hashes.is_empty() || self.pool.holds(head) {
                0
            } else {
                let s = &self.seqs[&head];
                self.pool.claim_prefix(head, &s.hashes, s.prompt_tokens)
            };
            if claimed >= prompt_tokens {
                // Full prefix hit (identical prompt re-served): no
                // prefill owed at all — decode the first token now.
                match self.pool.grow_to(head, prompt_tokens + 1) {
                    Ok(()) => {
                        self.waiting.pop_front();
                        self.running.push(head);
                        let s = known(self.seqs.get_mut(&head), head, "admit");
                        s.prefilled = prompt_tokens;
                        s.published = true; // pages are already in the trie
                        self.prefix_hit_tokens += claimed as u64;
                        plan.decode.push(head);
                    }
                    Err(short) => {
                        self.pool.retract_claim(head);
                        if self.running.is_empty() {
                            self.force_expand(short.0, &mut plan);
                            continue;
                        }
                        break;
                    }
                }
                continue;
            }
            let remaining = prompt_tokens - claimed;
            if budget == 0 {
                // No prefill budget left this tick; undo the claim so
                // the head re-claims (possibly more) next tick.
                if claimed > 0 {
                    self.pool.retract_claim(head);
                }
                break;
            }
            let len = remaining.min(budget);
            let last = len == remaining;
            match self.pool.grow_to(head, claimed + len + usize::from(last)) {
                Ok(()) => {
                    self.waiting.pop_front();
                    self.running.push(head);
                    let s = known(self.seqs.get_mut(&head), head, "admit");
                    s.prefilled = claimed + len;
                    self.prefix_hit_tokens += claimed as u64;
                    plan.admitted.push(head);
                    plan.prefill.push(ChunkTask { id: head, start: claimed, len, last });
                    budget -= len;
                }
                Err(short) => {
                    self.pool.retract_claim(head);
                    if self.running.is_empty() {
                        // Nothing running and the head alone does not
                        // fit: expand or the engine deadlocks.
                        self.force_expand(short.0, &mut plan);
                        continue;
                    }
                    break;
                }
            }
        }
        plan
    }

    /// Record one generated token for `id`; returns true when the
    /// sequence reached its token budget (caller should retire it).
    pub fn advance(&mut self, id: SeqId) -> bool {
        let s = known(self.seqs.get_mut(&id), id, "advance");
        s.generated += 1;
        s.generated >= s.max_new
    }

    /// Settle a speculative task for `id`: `emitted` verified tokens
    /// landed this tick (accepted draft prefix + the verifier's next
    /// token, so `1 ..= k + 1`). Rejected draft slack pages roll back
    /// to the new verified frontier — after this call the sequence's
    /// page state is exactly what a plain-decode run at the same
    /// `generated` count would hold. Returns true when the sequence
    /// reached its token budget. Pass `drafted` = the task's planned
    /// `k` so the acceptance counters attribute the split.
    pub fn advance_spec(&mut self, id: SeqId, drafted: usize, emitted: usize) -> bool {
        debug_assert!(emitted >= 1, "a verify step emits at least one token");
        let s = known(self.seqs.get_mut(&id), id, "advance_spec");
        s.generated += emitted.max(1);
        let done = s.generated >= s.max_new;
        let keep = s.prompt_tokens + s.generated;
        let accepted = emitted.max(1) - 1;
        self.spec_accepted += accepted as u64;
        self.spec_rejected += drafted.saturating_sub(accepted) as u64;
        self.pool.rollback_to(id, keep);
        done
    }

    /// Drop a finished (or cancelled) sequence and free its pages —
    /// including a sequence parked in host swap space (its host pages
    /// and resident refs are released).
    pub fn retire(&mut self, id: SeqId) {
        self.pool.release(id);
        if let Some(pos) = self.running.iter().position(|&r| r == id) {
            self.running.remove(pos);
        } else if let Some(pos) = self.waiting.iter().position(|&r| r == id) {
            let _ = self.waiting.remove(pos);
        } else if let Some(pos) = self.swapped_q.iter().position(|&r| r == id) {
            let _ = self.swapped_q.remove(pos);
        } else if let Some(pos) = self.migrate_q.iter().position(|&r| r == id) {
            let _ = self.migrate_q.remove(pos);
        }
        self.seqs.remove(&id);
    }

    /// Remove and return every tracked sequence (waiting first, then
    /// swapped, then migration-queued, then running, each FIFO),
    /// freeing all pages and host swap space — the worker-death path.
    /// No parked sequence is ever orphaned: a drained swapped or
    /// migration-queued id is handed back exactly like a waiting one.
    pub fn drain_ids(&mut self) -> Vec<SeqId> {
        let mut out: Vec<SeqId> = self.waiting.drain(..).collect();
        out.extend(self.swapped_q.drain(..));
        out.extend(self.migrate_q.drain(..));
        out.extend(self.running.drain(..));
        for &id in &out {
            self.pool.release(id);
        }
        self.seqs.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::kv::prompt_page_hashes;

    fn sched(pages: usize, page_tokens: usize, max_running: usize) -> IterationScheduler {
        IterationScheduler::new(KvPool::new(pages, page_tokens), max_running)
    }

    /// Drive the scheduler to completion, retiring sequences as they
    /// finish; returns (completion order, iterations used).
    fn run_to_completion(s: &mut IterationScheduler, max_iters: usize) -> (Vec<SeqId>, usize) {
        let mut order = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters <= max_iters, "scheduler failed to make progress");
            let plan = s.next_iteration();
            assert!(plan.batch() > 0, "a tick with sequences must advance something");
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    order.push(id);
                }
            }
        }
        (order, iters)
    }

    #[test]
    fn admission_is_fifo() {
        let mut s = sched(64, 16, 4);
        for id in 0..6u64 {
            s.enqueue(id, 16, 4);
        }
        let plan = s.next_iteration();
        assert_eq!(plan.admitted, vec![0, 1, 2, 3], "max_running caps the batch");
        assert!(plan.decode.is_empty());
        assert!(plan.prefill.iter().all(|c| c.last), "short prompts prefill whole");
        let plan2 = s.next_iteration();
        assert_eq!(plan2.decode, vec![0, 1, 2, 3]);
        assert!(plan2.admitted.is_empty(), "running set is full");
    }

    #[test]
    fn completion_frees_room_for_the_queue() {
        let mut s = sched(64, 16, 2);
        for id in 0..4u64 {
            s.enqueue(id, 8, 2);
        }
        let (order, _) = run_to_completion(&mut s, 64);
        assert_eq!(order, vec![0, 1, 2, 3], "FIFO completion under a count bound");
        assert_eq!(s.pool().in_use(), 0, "all pages returned");
        assert_eq!(s.preemptions(), 0);
    }

    #[test]
    fn pool_exhaustion_preempts_newest_and_requeues_front() {
        // 4 pages of 16 tokens; each seq needs 2 pages at admission
        // (prompt 17 -> 2 pages) and grows into a 3rd page later
        // (17 + 16 = 33 tokens -> 3 pages at generated = 16).
        let mut s = sched(4, 16, 8);
        s.enqueue(0, 17, 20);
        s.enqueue(1, 17, 20);
        let first = s.next_iteration();
        assert_eq!(first.admitted, vec![0, 1]);
        // Tick until growth forces a preemption: seq 1 (newest) must be
        // the victim, exactly once, and re-admit after 0 retires.
        let mut preempted_events: Vec<SeqId> = Vec::new();
        let mut done: Vec<SeqId> = Vec::new();
        let mut iters = 0;
        // Consume the first tick's tokens.
        for id in first.producers() {
            assert!(!s.advance(id));
        }
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 200, "no deadlock allowed");
            let plan = s.next_iteration();
            preempted_events.extend(&plan.preempted);
            assert!(plan.batch() > 0);
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1], "both sequences complete, oldest first");
        assert!(!preempted_events.is_empty(), "the tight pool must preempt");
        assert!(
            preempted_events.iter().all(|&id| id == 1),
            "only the newest sequence may be preempted: {preempted_events:?}"
        );
        assert_eq!(s.forced_expansions(), 0, "a sane pool never force-expands");
        assert!(s.pool().peak_in_use() <= 4, "occupancy may never exceed the pool");
    }

    #[test]
    fn many_sequences_tiny_pool_never_deadlocks() {
        let mut s = sched(6, 8, 64);
        for id in 0..12u64 {
            s.enqueue(id, 12, 24); // worst case 12+24 = 36 tokens = 5 pages
        }
        let (order, _) = run_to_completion(&mut s, 5_000);
        assert_eq!(order.len(), 12);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 12, "exactly-once completion");
        assert_eq!(s.forced_expansions(), 0);
        assert!(s.pool().peak_in_use() <= 6);
    }

    #[test]
    fn oversized_sequence_forces_expansion_instead_of_deadlock() {
        // Pool of 2 pages cannot hold a 100-token prompt (7 pages).
        let mut s = sched(2, 16, 4);
        s.enqueue(0, 100, 4);
        let (order, _) = run_to_completion(&mut s, 32);
        assert_eq!(order, vec![0]);
        assert!(s.forced_expansions() >= 1, "expansion must be accounted");
    }

    #[test]
    fn preempted_sequence_restarts_from_scratch() {
        let mut s = sched(4, 16, 8);
        s.enqueue(0, 17, 40);
        s.enqueue(1, 17, 40);
        let mut total_advances_for_1 = 0usize;
        let mut saw_preempt = false;
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 500);
            let plan = s.next_iteration();
            if plan.preempted.contains(&1) {
                saw_preempt = true;
            }
            for id in plan.producers() {
                if id == 1 {
                    total_advances_for_1 += 1;
                }
                if s.advance(id) {
                    s.retire(id);
                }
            }
        }
        assert!(saw_preempt);
        assert!(
            total_advances_for_1 > 40,
            "recompute must replay preempted progress ({total_advances_for_1} advances)"
        );
    }

    #[test]
    fn resize_down_blocks_admission_until_drain() {
        let mut s = sched(8, 16, 8);
        s.enqueue(0, 30, 4); // 2 pages minimum
        let plan = s.next_iteration();
        assert_eq!(plan.admitted, vec![0]);
        s.resize_pool(1); // below the running seq's footprint
        s.enqueue(1, 30, 4);
        // Seq 1 cannot be admitted while 0 holds the over-committed
        // pool, but 0 still runs (forced expansion only grows to cover
        // growth of the lone running seq).
        let plan2 = s.next_iteration();
        assert_eq!(plan2.decode, vec![0]);
        assert!(plan2.admitted.is_empty());
        (0..4).for_each(|_| {
            if s.advance(0) {
                s.retire(0);
            }
        });
        assert!(!s.running.contains(&0));
        // With 0 gone the pool drains; seq 1 admits (forced expansion
        // may fire because 1 page < one sequence).
        let plan3 = s.next_iteration();
        assert_eq!(plan3.admitted, vec![1]);
    }

    #[test]
    fn drain_returns_everything_and_frees_pages() {
        let mut s = sched(16, 16, 2);
        for id in 0..5u64 {
            s.enqueue(id, 16, 4);
        }
        let _ = s.next_iteration(); // admit 0, 1
        let ids = s.drain_ids();
        assert_eq!(ids.len(), 5);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        assert_eq!(s.pool().in_use(), 0);
        assert!(s.is_idle());
    }

    // ---- Chunked prefill ----

    #[test]
    fn long_prompt_prefills_in_budgeted_chunks() {
        let mut s = sched(64, 16, 8);
        s.set_prefill_chunk(32);
        s.enqueue(0, 100, 3);
        // Tick 1: admit + first 32-token chunk, no token produced.
        let p1 = s.next_iteration();
        assert_eq!(p1.admitted, vec![0]);
        assert_eq!(p1.prefill, vec![ChunkTask { id: 0, start: 0, len: 32, last: false }]);
        assert!(p1.decode.is_empty());
        assert!(p1.producers().is_empty(), "mid-prefill produces nothing");
        // Ticks 2-3: carried-over chunks.
        let p2 = s.next_iteration();
        assert_eq!(p2.prefill, vec![ChunkTask { id: 0, start: 32, len: 32, last: false }]);
        let p3 = s.next_iteration();
        assert_eq!(p3.prefill, vec![ChunkTask { id: 0, start: 64, len: 32, last: false }]);
        // Tick 4: the last 4 tokens complete prefill -> first token.
        let p4 = s.next_iteration();
        assert_eq!(p4.prefill, vec![ChunkTask { id: 0, start: 96, len: 4, last: true }]);
        assert_eq!(p4.producers(), vec![0]);
        assert!(!s.advance(0));
        // From here on it decodes.
        let p5 = s.next_iteration();
        assert_eq!(p5.decode, vec![0]);
        assert!(p5.prefill.is_empty());
    }

    #[test]
    fn chunk_budget_interleaves_prefill_with_decode() {
        let mut s = sched(64, 16, 8);
        s.set_prefill_chunk(16);
        s.enqueue(0, 8, 8); // short: decodes immediately
        let p = s.next_iteration();
        assert!(!s.advance(0));
        assert_eq!(p.producers(), vec![0]);
        s.enqueue(1, 64, 4); // long: 4 chunks of 16
        for tick in 0..4 {
            let p = s.next_iteration();
            assert_eq!(p.decode, vec![0], "decode keeps running during prefill (tick {tick})");
            assert_eq!(p.prefill.len(), 1);
            assert_eq!(p.prefill[0].len, 16);
            assert!(!s.advance(0));
            if p.prefill[0].last {
                assert!(!s.advance(1));
            }
        }
        // Both now decode together.
        let p = s.next_iteration();
        assert_eq!(p.decode, vec![0, 1]);
    }

    #[test]
    fn chunk_budget_is_shared_across_admissions() {
        let mut s = sched(64, 16, 8);
        s.set_prefill_chunk(48);
        for id in 0..3u64 {
            s.enqueue(id, 32, 2);
        }
        // 48-token budget covers seq 0 (32) and half of seq 1 (16);
        // seq 2 must wait for budget even though pages are free.
        let p1 = s.next_iteration();
        assert_eq!(p1.admitted, vec![0, 1]);
        assert_eq!(p1.prefill[0], ChunkTask { id: 0, start: 0, len: 32, last: true });
        assert_eq!(p1.prefill[1], ChunkTask { id: 1, start: 0, len: 16, last: false });
        assert!(!s.advance(0));
        let p2 = s.next_iteration();
        assert_eq!(p2.admitted, vec![2]);
        assert_eq!(p2.prefill[0], ChunkTask { id: 1, start: 16, len: 16, last: true });
        assert_eq!(p2.prefill[1], ChunkTask { id: 2, start: 0, len: 32, last: true });
    }

    #[test]
    fn preempted_partial_prefill_restarts_cleanly() {
        // Tight pool: a long prompt mid-prefill is preempted by the
        // older decoder's growth and must re-prefill from scratch.
        let mut s = sched(4, 16, 8);
        s.set_prefill_chunk(16);
        s.enqueue(0, 17, 24); // 2 pages, grows to 3
        s.enqueue(1, 40, 2); // 3 pages over 3 chunks
        let mut chunks_for_1: Vec<ChunkTask> = Vec::new();
        let mut done = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 300, "no deadlock");
            let plan = s.next_iteration();
            chunks_for_1.extend(plan.prefill.iter().filter(|c| c.id == 1));
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1]);
        assert!(s.preemptions() > 0, "the tight pool must preempt the prefill");
        // After each preemption the chunk offsets restart at 0.
        let restarts = chunks_for_1.iter().filter(|c| c.start == 0).count();
        assert!(restarts >= 2, "re-admission must re-prefill from scratch");
        assert_eq!(s.pool().in_use(), 0);
        assert_eq!(s.pool().trie_len(), 0);
    }

    // ---- Swap-to-host preemption ----

    /// Swap-enabled config with zero cost rates: swap always wins the
    /// per-victim comparison while the budget holds.
    fn swap_cfg(swap_pages: usize) -> PreemptionConfig {
        PreemptionConfig {
            mode: PreemptionMode::Swap,
            swap_pages,
            ..PreemptionConfig::default()
        }
    }

    #[test]
    fn swap_eviction_checkpoints_decode_progress() {
        // Same tight-pool collision as the recompute test, but with
        // swap enabled the victim must NOT replay any token: total
        // advances per sequence equal max_new exactly.
        let mut s = sched(4, 16, 8);
        s.set_preemption(swap_cfg(64));
        s.enqueue(0, 17, 20);
        s.enqueue(1, 17, 20);
        let mut advances: std::collections::HashMap<SeqId, usize> =
            std::collections::HashMap::new();
        let mut swap_out_events = 0usize;
        let mut swap_in_events = 0usize;
        let mut done = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 300, "no deadlock");
            let plan = s.next_iteration();
            assert!(plan.preempted.is_empty(), "swap must replace recompute here");
            swap_out_events += plan.swapped_out.len();
            swap_in_events += plan.swapped_in.len();
            for id in plan.producers() {
                *advances.entry(id).or_insert(0) += 1;
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1], "oldest finishes first");
        assert!(swap_out_events > 0, "the tight pool must swap");
        assert_eq!(swap_out_events, swap_in_events, "every park resumes exactly once");
        assert_eq!(advances[&0], 20, "never preempted");
        assert_eq!(advances[&1], 20, "checkpointed: no token is ever recomputed");
        assert_eq!(s.preemptions(), 0);
        let (outs, ins, moves) = s.swap_counts();
        assert_eq!(outs as usize, swap_out_events);
        assert_eq!(ins as usize, swap_in_events);
        assert!(moves > 0);
        assert_eq!(s.pool().in_use(), 0);
        assert_eq!(s.pool().swapped_pages(), 0);
        s.pool().validate().unwrap();
    }

    #[test]
    fn swap_eviction_checkpoints_partial_prefill() {
        // A long prompt mid-prefill is evicted by the older decoder's
        // growth; with swap enabled its completed chunks survive and
        // prefill resumes mid-prompt — chunk starts never return to 0.
        let mut s = sched(4, 16, 8);
        s.set_preemption(swap_cfg(64));
        s.set_prefill_chunk(16);
        s.enqueue(0, 17, 24); // 2 pages, grows to 3
        s.enqueue(1, 40, 2); // 3 pages over 3 chunks
        let mut chunks_for_1: Vec<ChunkTask> = Vec::new();
        let mut swapped_1 = 0usize;
        let mut done = Vec::new();
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 300, "no deadlock");
            let plan = s.next_iteration();
            swapped_1 += plan.swapped_out.iter().filter(|&&(id, _)| id == 1).count();
            chunks_for_1.extend(plan.prefill.iter().filter(|c| c.id == 1));
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                    done.push(id);
                }
            }
        }
        assert_eq!(done, vec![0, 1]);
        assert!(swapped_1 > 0, "the tight pool must park the prefilling seq");
        let total: usize = chunks_for_1.iter().map(|c| c.len).sum();
        assert_eq!(total, 40, "every prompt token is prefilled exactly once");
        let restarts = chunks_for_1.iter().filter(|c| c.start == 0).count();
        assert_eq!(restarts, 1, "checkpointed resume never returns to token 0");
        // Consecutive chunks are contiguous across the park.
        for w in chunks_for_1.windows(2) {
            assert_eq!(w[0].start + w[0].len, w[1].start, "chunks stay contiguous");
        }
        assert_eq!(s.pool().in_use(), 0);
        s.pool().validate().unwrap();
    }

    #[test]
    fn swap_budget_exhaustion_falls_back_to_recompute() {
        // Swap allowed but a zero-page host budget: eviction must
        // degrade to the recompute discipline, not wedge.
        let mut s = sched(4, 16, 8);
        s.set_preemption(swap_cfg(0));
        s.enqueue(0, 17, 20);
        s.enqueue(1, 17, 20);
        let mut preempted = 0usize;
        let mut swapped = 0usize;
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 300);
            let plan = s.next_iteration();
            preempted += plan.preempted.len();
            swapped += plan.swapped_out.len();
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                }
            }
        }
        assert!(preempted > 0, "no budget: recompute must fire");
        assert_eq!(swapped, 0);
        assert_eq!(s.swap_counts(), (0, 0, 0));
    }

    #[test]
    fn per_victim_cost_choice_prefers_cheaper_discipline() {
        // Expensive swap, cheap recompute: stay on recompute even in
        // Swap mode.
        let mut s = sched(4, 16, 8);
        s.set_preemption(PreemptionConfig {
            mode: PreemptionMode::Swap,
            swap_pages: 64,
            prefill_s_per_token: 1e-6,
            swap_s_per_page: 1.0, // absurdly slow PCIe
            page_bytes: 0.0,
        });
        s.enqueue(0, 17, 20);
        s.enqueue(1, 17, 20);
        let mut preempted = 0usize;
        let mut swapped = 0usize;
        let mut iters = 0;
        while !s.is_idle() {
            iters += 1;
            assert!(iters < 500);
            let plan = s.next_iteration();
            preempted += plan.preempted.len();
            swapped += plan.swapped_out.len();
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                }
            }
        }
        assert!(preempted > 0);
        assert_eq!(swapped, 0, "a dear PCIe must never be chosen");
    }

    #[test]
    fn resumed_sequences_outrank_new_admissions() {
        // Seq 1 parks under pressure from seq 0; seq 2 arrives while 1
        // is parked. On drain, 1 must resume BEFORE 2 is admitted.
        let mut s = sched(4, 16, 8);
        s.set_preemption(swap_cfg(64));
        s.enqueue(0, 17, 24);
        s.enqueue(1, 17, 24);
        // Tick until seq 1 is parked.
        let mut iters = 0;
        while s.n_swapped() == 0 {
            iters += 1;
            assert!(iters < 100, "pressure must park seq 1");
            let plan = s.next_iteration();
            for id in plan.producers() {
                assert!(!s.advance(id), "budgets are deep enough here");
            }
        }
        s.enqueue(2, 17, 4);
        // While 1 is parked, 2 must not be admitted.
        let mut resumed_at: Option<usize> = None;
        let mut admitted_2_at: Option<usize> = None;
        let mut tick = 0;
        while !s.is_idle() {
            tick += 1;
            assert!(tick < 500, "no deadlock");
            let plan = s.next_iteration();
            if plan.swapped_in.iter().any(|&(id, _)| id == 1) && resumed_at.is_none() {
                resumed_at = Some(tick);
            }
            if plan.admitted.contains(&2) && admitted_2_at.is_none() {
                admitted_2_at = Some(tick);
            }
            for id in plan.producers() {
                if s.advance(id) {
                    s.retire(id);
                }
            }
        }
        let r = resumed_at.expect("seq 1 must resume");
        let a = admitted_2_at.expect("seq 2 must eventually run");
        assert!(r <= a, "checkpointed work resumes before new admissions ({r} vs {a})");
    }

    #[test]
    fn drain_returns_swapped_sequences_too() {
        let mut s = sched(4, 16, 8);
        s.set_preemption(swap_cfg(64));
        s.enqueue(0, 17, 24);
        s.enqueue(1, 17, 24);
        let mut iters = 0;
        while s.n_swapped() == 0 {
            iters += 1;
            assert!(iters < 100);
            let plan = s.next_iteration();
            for id in plan.producers() {
                let _ = s.advance(id);
            }
        }
        s.enqueue(2, 16, 4); // still waiting
        let ids = s.drain_ids();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "waiting + running + swapped all drain");
        assert!(s.is_idle());
        assert_eq!(s.pool().in_use(), 0);
        assert_eq!(s.pool().swapped_pages(), 0, "no parked sequence is orphaned");
        s.pool().validate().unwrap();
    }

    #[test]
    fn retire_of_a_parked_sequence_frees_swap_space() {
        let mut s = sched(4, 16, 8);
        s.set_preemption(swap_cfg(64));
        s.enqueue(0, 17, 24);
        s.enqueue(1, 17, 24);
        let mut iters = 0;
        while s.n_swapped() == 0 {
            iters += 1;
            assert!(iters < 100);
            let plan = s.next_iteration();
            for id in plan.producers() {
                let _ = s.advance(id);
            }
        }
        s.retire(1); // cancel the parked sequence
        assert_eq!(s.n_swapped(), 0);
        assert_eq!(s.pool().swapped_pages(), 0);
        let (order, _) = run_to_completion(&mut s, 200);
        assert_eq!(order, vec![0]);
        s.pool().validate().unwrap();
    }

    // ---- Prefix sharing through the scheduler ----

    fn hashes_of(seed: i32, len: usize, pt: usize) -> Vec<u64> {
        let prompt: Vec<i32> =
            (0..len as i32).map(|i| seed.wrapping_mul(977).wrapping_add(i)).collect();
        prompt_page_hashes(&prompt, pt)
    }

    #[test]
    fn full_prefix_hit_skips_prefill_entirely() {
        let mut s = sched(64, 16, 8);
        let h = hashes_of(1, 48, 16);
        s.enqueue_shared(0, 48, 4, h.clone());
        let p1 = s.next_iteration();
        assert_eq!(p1.admitted, vec![0]);
        assert_eq!(p1.prefill_tokens(), 48, "first serve prefills everything");
        assert!(!s.advance(0));
        let _ = s.next_iteration(); // publishes seq 0's pages
        // An identical prompt (a cascade re-serve) claims every page:
        // no prefill chunk, first token decoded immediately.
        s.enqueue_shared(1, 48, 4, h);
        let p = s.next_iteration();
        assert!(p.admitted.is_empty(), "full hits owe no prefill");
        assert!(p.decode.contains(&1));
        assert!(p.prefill.is_empty());
        assert_eq!(s.prefix_hit_tokens(), 48);
        assert!(!s.advance(1));
        // Physical occupancy: 48-token prompt = 3 pages shared + one
        // private first-token page each.
        assert!(s.pool().in_use() <= 3 + 2, "shared pages must not be duplicated");
    }

    #[test]
    fn partial_prefix_hit_prefills_only_the_tail() {
        let mut s = sched(64, 16, 8);
        // Two prompts sharing the first 32 tokens (2 pages), diverging
        // in the tail page.
        let shared: Vec<i32> = (0..32).collect();
        let mut a = shared.clone();
        a.extend(100..116);
        let mut b = shared;
        b.extend(200..216);
        s.enqueue_shared(0, 48, 4, prompt_page_hashes(&a, 16));
        let _ = s.next_iteration();
        assert!(!s.advance(0));
        let _ = s.next_iteration(); // publish
        s.enqueue_shared(1, 48, 4, prompt_page_hashes(&b, 16));
        let p = s.next_iteration();
        let chunk = p.prefill.iter().find(|c| c.id == 1).expect("tail chunk");
        assert_eq!(chunk.start, 32, "shared pages skip prefill");
        assert_eq!(chunk.len, 16);
        assert!(chunk.last);
        assert_eq!(s.prefix_hit_tokens(), 32);
    }

    #[test]
    fn retire_and_drain_leave_no_shared_residue() {
        let mut s = sched(32, 16, 8);
        let h = hashes_of(7, 64, 16);
        let free0 = s.pool().free_pages();
        // Seq 0 prefills and publishes; 1 and 2 arrive while it still
        // runs and ride its pages.
        s.enqueue_shared(0, 64, 8, h.clone());
        for id in s.next_iteration().producers() {
            assert!(!s.advance(id));
        }
        let _ = s.next_iteration(); // publish tick
        assert!(!s.advance(0));
        s.enqueue_shared(1, 64, 2, h.clone());
        s.enqueue_shared(2, 64, 2, h);
        let (order, _) = run_to_completion(&mut s, 64);
        assert_eq!(order.len(), 3);
        assert!(s.prefix_hit_tokens() > 0, "later arrivals must hit the trie");
        assert_eq!(s.pool().in_use(), 0, "refcount leak");
        assert_eq!(s.pool().trie_len(), 0, "trie leak");
        assert_eq!(s.pool().free_pages(), free0, "free list must return to initial");
    }

    // ---- Prefill/decode migration ----

    #[test]
    fn prefill_role_hands_off_after_first_token() {
        let mut p = sched(64, 16, 8);
        p.set_role(EngineRole::Prefill);
        let mut d = sched(64, 16, 8);
        d.set_role(EngineRole::Decode);
        p.enqueue(0, 48, 4);
        let t1 = p.next_iteration();
        assert_eq!(t1.admitted, vec![0]);
        assert!(t1.prefill.iter().any(|c| c.id == 0 && c.last));
        assert!(!p.advance(0)); // first token produced on the prefill side
        // Next tick: the finished prefill leaves instead of decoding.
        let t2 = p.next_iteration();
        assert_eq!(t2.migrated_out.len(), 1);
        let (id, pages) = t2.migrated_out[0];
        assert_eq!(id, 0);
        assert!(pages > 0);
        assert!(t2.decode.is_empty());
        assert!(p.is_idle(), "the prefill side forgets the sequence");
        assert_eq!(p.pool().in_use(), 0, "handoff releases every page");
        p.pool().validate().unwrap();
        // Decode side: admits ahead of fresh work and decodes this tick.
        d.enqueue_prefilled(0, 48, 1, 4, Vec::new());
        let t3 = d.next_iteration();
        assert_eq!(t3.migrated_in.len(), 1);
        assert!(t3.migrated_in[0].1 > 0, "private pages crossed the link");
        assert_eq!(t3.decode, vec![0]);
        let (order, _) = run_to_completion(&mut d, 16);
        assert_eq!(order, vec![0]);
        d.pool().validate().unwrap();
        assert_eq!(d.pool().in_use(), 0);
        let (outs, _, pages_out, _) = p.migrate_counts();
        let (_, ins, _, pages_in) = d.migrate_counts();
        assert_eq!((outs, ins), (1, 1));
        assert_eq!(pages_out, pages_in, "both sides account the same transfer");
    }

    #[test]
    fn migrated_sequences_reclaim_shared_prefix_from_decode_trie() {
        let pt = 16;
        let h = hashes_of(3, 48, pt);
        let mut d = sched(64, pt, 8);
        d.set_role(EngineRole::Decode);
        // First migrant carries everything; once resident it publishes
        // its prompt pages into the decode-side trie.
        d.enqueue_prefilled(10, 48, 1, 8, h.clone());
        let t1 = d.next_iteration();
        assert_eq!(t1.migrated_in.len(), 1);
        let first_pages = t1.migrated_in[0].1;
        assert!(first_pages >= 3);
        assert!(!d.advance(10));
        let _ = d.next_iteration(); // publish tick
        // Second migrant with the same prompt: the prefix re-claims
        // from the local trie, only the private remainder crosses the
        // link.
        d.enqueue_prefilled(11, 48, 1, 8, h);
        let t3 = d.next_iteration();
        let (_, pages) = t3.migrated_in.iter().copied().find(|&(id, _)| id == 11).unwrap();
        assert!(
            pages < first_pages,
            "shared prefix pages must not move: {pages} vs {first_pages}"
        );
        assert!(d.pool().shared_claims() > 0);
        d.retire(10);
        d.retire(11);
        d.pool().validate().unwrap();
        assert_eq!(d.pool().in_use(), 0);
        assert_eq!(d.pool().trie_len(), 0);
    }

    #[test]
    fn closed_migration_degrades_to_unified_decode() {
        let mut p = sched(64, 16, 8);
        p.set_role(EngineRole::Prefill);
        p.set_migration_open(false); // no live decode worker
        p.enqueue(0, 32, 3);
        let (order, _) = run_to_completion(&mut p, 32);
        assert_eq!(order, vec![0], "the sequence completes locally");
        let (outs, ins, _, _) = p.migrate_counts();
        assert_eq!((outs, ins), (0, 0));
        // Re-opening later must not re-offer a sequence that already
        // decoded past its first token.
        let mut q = sched(64, 16, 8);
        q.set_role(EngineRole::Prefill);
        q.set_migration_open(false);
        q.enqueue(1, 32, 8);
        let _ = q.next_iteration(); // prefill (+ first token)
        assert!(!q.advance(1));
        let _ = q.next_iteration(); // decodes locally, generated -> 2
        assert!(!q.advance(1));
        q.set_migration_open(true);
        let t = q.next_iteration();
        assert!(t.migrated_out.is_empty(), "mid-decode sequences stay local");
        assert_eq!(t.decode, vec![1]);
    }

    #[test]
    fn returned_handoffs_stay_local_on_the_prefill_worker() {
        // A handoff the hub could not place comes back via
        // enqueue_prefilled: it is pinned local and never re-offered,
        // even with migration open.
        let mut p = sched(64, 16, 8);
        p.set_role(EngineRole::Prefill);
        p.enqueue_prefilled(5, 32, 1, 3, Vec::new());
        let t = p.next_iteration();
        assert_eq!(t.migrated_in.len(), 1);
        assert!(t.migrated_out.is_empty());
        let (order, _) = run_to_completion(&mut p, 16);
        assert_eq!(order, vec![5]);
        let (outs, _, _, _) = p.migrate_counts();
        assert_eq!(outs, 0);
    }

    #[test]
    fn drain_returns_migration_queued_sequences() {
        let mut d = sched(8, 16, 4);
        d.set_role(EngineRole::Decode);
        d.enqueue_prefilled(1, 64, 1, 4, Vec::new());
        d.enqueue_prefilled(2, 64, 1, 4, Vec::new());
        let t = d.next_iteration();
        // 64+2 tokens = 5 pages each: the second migrant cannot fit
        // while the first runs — it stays queued, never evicting.
        assert_eq!(t.migrated_in.len(), 1);
        assert_eq!(d.n_migrate_queued(), 1);
        let drained = d.drain_ids();
        assert_eq!(drained, vec![2, 1], "queued migrants drain like waiting work");
        assert_eq!(d.pool().in_use(), 0);
        d.pool().validate().unwrap();
    }
}
