//! Shared evaluation harness for the paper-figure binaries
//! (`rust/bin/fig*.rs`, `table*.rs`): plan construction for all three
//! systems, cascade simulation, and the standard experiment cases.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::baselines;
use crate::cluster::ClusterSpec;
use crate::coordinator::cascade_sim::{simulate_cascade, CascadeSimResult};
use crate::judge::Judger;
use crate::models::ModelSpec;
use crate::sched::outer::{optimize, select_plan, OuterOptions, SweepResult};
use crate::sched::plan::CascadePlan;
use crate::workload::{generate, paper_trace, Request, TraceSpec};

/// The (quality requirement, trace index) cases of the paper's case
/// studies (Tables 1-2, Figures 10-11).
pub const PAPER_CASES: [(f64, usize); 6] =
    [(90.0, 1), (85.0, 1), (80.0, 1), (80.0, 2), (80.0, 3), (70.0, 3)];

/// Default arrival rates per trace chosen so the 32-GPU cluster is
/// meaningfully loaded: standalone DeepSeek-671B runs at ~90% of its
/// modeled capacity (and 70B near ~90%), so its queueing
/// tail explodes, while the cascade — which serves most requests at
/// cheap tiers — keeps headroom. This is the operating regime of the
/// paper's Figures 7-8.
pub fn default_rate(trace_index: usize) -> f64 {
    match trace_index {
        1 => 64.0,
        2 => 80.0,
        _ => 126.0,
    }
}

/// A fully-specified evaluation scenario.
pub struct Scenario {
    pub cascade: Vec<ModelSpec>,
    pub cluster: ClusterSpec,
    pub judger: Judger,
    /// Planning trace (scheduler input).
    pub plan_reqs: Vec<Request>,
    /// Evaluation trace (fresh seed, same distribution).
    pub eval_reqs: Vec<Request>,
    pub spec: TraceSpec,
}

impl Scenario {
    pub fn new(
        cascade: Vec<ModelSpec>,
        n_gpus: usize,
        trace_index: usize,
        rate: f64,
        n_requests: usize,
        seed: u64,
    ) -> Scenario {
        let spec = paper_trace(trace_index, rate);
        Scenario {
            cascade,
            cluster: ClusterSpec::with_gpus(n_gpus),
            judger: Judger::new(seed),
            plan_reqs: generate(&spec, n_requests, seed.wrapping_add(1)),
            eval_reqs: generate(&spec, n_requests, seed.wrapping_add(2)),
            spec,
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.cluster.total_gpus()
    }

    /// Run the full bi-level scheduler; returns the sweep and elapsed
    /// seconds.
    pub fn schedule(&self, opts: &OuterOptions) -> Result<(SweepResult, f64)> {
        let t0 = Instant::now();
        let sweep = optimize(
            &self.cascade,
            &self.cluster,
            &self.judger,
            &self.plan_reqs,
            self.n_gpus(),
            opts,
        )?;
        Ok((sweep, t0.elapsed().as_secs_f64()))
    }

    /// Cascadia's plan for a quality requirement.
    pub fn cascadia_plan(&self, quality_req: f64, opts: &OuterOptions) -> Result<CascadePlan> {
        let (sweep, _) = self.schedule(opts)?;
        select_plan(&sweep, quality_req)
            .with_context(|| format!("no Cascadia plan meets quality {quality_req}"))
    }

    /// Stand-alone baseline: the paper compares against 671B for
    /// quality >= 85 and the mid model below that (§4.1).
    pub fn standalone_plan(&self, quality_req: f64) -> Result<CascadePlan> {
        let idx = if quality_req >= 85.0 || self.cascade.len() == 2 {
            self.cascade.len() - 1
        } else {
            self.cascade.len() - 2
        };
        baselines::standalone_plan(
            idx,
            &self.cascade,
            &self.cluster,
            &self.judger,
            &self.plan_reqs,
            self.n_gpus(),
        )
    }

    pub fn cascade_serve_plan(&self, quality_req: f64) -> Result<CascadePlan> {
        baselines::cascade_serve_plan(
            &self.cascade,
            &self.cluster,
            &self.judger,
            &self.plan_reqs,
            self.n_gpus(),
            quality_req,
        )
    }

    /// Simulate a plan on the held-out evaluation trace.
    pub fn evaluate(&self, plan: &CascadePlan) -> Result<CascadeSimResult> {
        simulate_cascade(plan, &self.cascade, &self.cluster, &self.judger, &self.eval_reqs)
    }
}

/// The paper's SLO unit: the system's average single-request processing
/// latency (we use the cascade's lightly-loaded mean so all systems
/// share one unit per scenario).
pub fn slo_unit(scenario: &Scenario, plan: &CascadePlan) -> Result<f64> {
    // Simulate a sparse trace (1/20 of the requests, stretched 20x) to
    // approximate zero-queueing single-request latency.
    let sparse: Vec<Request> = scenario
        .eval_reqs
        .iter()
        .step_by(20)
        .enumerate()
        .map(|(i, r)| Request { arrival: i as f64 * 20.0 / scenario.spec.rate.max(0.1), ..*r })
        .collect();
    let out = simulate_cascade(plan, &scenario.cascade, &scenario.cluster,
                               &scenario.judger, &sparse)?;
    Ok(out.mean())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;

    #[test]
    fn scenario_builds_and_evaluates() {
        let s = Scenario::new(deepseek_cascade(), 32, 2, 4.0, 300, 7);
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 50.0, 90.0],
            ..Default::default()
        };
        let plan = s.cascadia_plan(75.0, &opts).unwrap();
        let out = s.evaluate(&plan).unwrap();
        assert_eq!(out.e2e_latencies.len(), 300);
        assert!(out.quality >= 70.0);
        let unit = slo_unit(&s, &plan).unwrap();
        assert!(unit > 0.0 && unit < 100.0);
    }

    #[test]
    fn alternate_policy_families_schedule_and_evaluate() {
        use crate::router::PolicyKind;
        let s = Scenario::new(deepseek_cascade(), 32, 2, 4.0, 300, 7);
        for kind in [PolicyKind::Length, PolicyKind::Margin] {
            let opts = OuterOptions {
                threshold_grid: vec![0.0, 50.0, 90.0],
                policy_kind: kind,
                ..Default::default()
            };
            let (sweep, _) = s.schedule(&opts).unwrap();
            // At least one plan of the swept family must make it through
            // the whole pipeline: schedule -> plan -> held-out DES.
            let mut evaluated = false;
            for p in sweep
                .pareto
                .iter()
                .chain(&sweep.explored)
                .filter(|p| p.plan.policy.kind() == kind)
            {
                if let Ok(out) = s.evaluate(&p.plan) {
                    assert_eq!(out.e2e_latencies.len(), 300);
                    assert!(out.p95().is_finite());
                    evaluated = true;
                    break;
                }
            }
            assert!(evaluated, "{kind:?}: no swept plan evaluated end-to-end");
        }
    }

    #[test]
    fn three_systems_produce_plans() {
        let s = Scenario::new(deepseek_cascade(), 32, 2, 4.0, 300, 7);
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 50.0, 90.0],
            ..Default::default()
        };
        let a = s.cascadia_plan(80.0, &opts).unwrap();
        let b = s.standalone_plan(80.0).unwrap();
        let c = s.cascade_serve_plan(80.0).unwrap();
        for p in [&a, &b, &c] {
            assert_eq!(p.total_gpus(), 32);
        }
        // Stand-alone for q=80 should be the mid model.
        assert_eq!(b.deployed().count(), 1);
    }
}
