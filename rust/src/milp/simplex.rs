//! Two-phase dense-tableau simplex.
//!
//! Solves `min/max c·x` subject to `A x {<=,>=,=} b`, `x >= 0`, via the
//! textbook two-phase method: phase 1 minimizes the sum of artificial
//! variables to find a feasible basis, phase 2 optimizes the real
//! objective. Entering variable uses Dantzig's rule with a Bland's-rule
//! fallback after a stall budget, which guarantees termination.
//!
//! Scale target: the scheduler's LPs are a few hundred variables/rows;
//! a dense tableau is simple and fast at that size.

/// Constraint relation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rel {
    Le,
    Ge,
    Eq,
}

/// Optimization sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Minimize,
    Maximize,
}

#[derive(Debug, PartialEq)]
pub enum LpError {
    Infeasible(f64),
    Unbounded,
    IterationLimit,
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Infeasible(v) => {
                write!(f, "LP is infeasible (phase-1 objective {v} > 0)")
            }
            LpError::Unbounded => write!(f, "LP is unbounded"),
            LpError::IterationLimit => write!(f, "simplex iteration limit hit"),
        }
    }
}

impl std::error::Error for LpError {}

/// An LP in natural form: variables are implicitly `>= 0`.
#[derive(Debug, Clone)]
pub struct LpProblem {
    pub n: usize,
    pub objective: Vec<f64>,
    pub sense: Sense,
    rows: Vec<(Vec<f64>, Rel, f64)>,
}

/// Solution: primal values and objective value (in the user's sense).
#[derive(Debug, Clone, PartialEq)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub value: f64,
}

const EPS: f64 = 1e-9;
const MAX_ITERS: usize = 20_000;

impl LpProblem {
    pub fn new(n: usize, objective: Vec<f64>, sense: Sense) -> LpProblem {
        assert_eq!(objective.len(), n);
        LpProblem { n, objective, sense, rows: Vec::new() }
    }

    /// Add a constraint `coeffs . x (rel) rhs`.
    pub fn constrain(&mut self, coeffs: Vec<f64>, rel: Rel, rhs: f64) {
        assert_eq!(coeffs.len(), self.n);
        self.rows.push((coeffs, rel, rhs));
    }

    /// Convenience: bound a single variable (`x_i <= hi`, `x_i >= lo`).
    pub fn bound(&mut self, i: usize, lo: Option<f64>, hi: Option<f64>) {
        if let Some(lo) = lo {
            if lo > 0.0 {
                let mut c = vec![0.0; self.n];
                c[i] = 1.0;
                self.constrain(c, Rel::Ge, lo);
            }
        }
        if let Some(hi) = hi {
            let mut c = vec![0.0; self.n];
            c[i] = 1.0;
            self.constrain(c, Rel::Le, hi);
        }
    }

    /// Solve with two-phase simplex.
    pub fn solve(&self) -> Result<LpSolution, LpError> {
        // Internally minimize; flip sign for Maximize.
        let obj: Vec<f64> = match self.sense {
            Sense::Minimize => self.objective.clone(),
            Sense::Maximize => self.objective.iter().map(|c| -c).collect(),
        };

        let m = self.rows.len();
        // Normalize rows to rhs >= 0.
        let mut rows: Vec<(Vec<f64>, Rel, f64)> = self.rows.clone();
        for (coeffs, rel, rhs) in rows.iter_mut() {
            if *rhs < 0.0 {
                for c in coeffs.iter_mut() {
                    *c = -*c;
                }
                *rhs = -*rhs;
                *rel = match *rel {
                    Rel::Le => Rel::Ge,
                    Rel::Ge => Rel::Le,
                    Rel::Eq => Rel::Eq,
                };
            }
        }

        // Column layout: [structural | slacks/surplus | artificials | rhs]
        let n_slack = rows
            .iter()
            .filter(|(_, rel, _)| !matches!(rel, Rel::Eq))
            .count();
        let n_art = rows
            .iter()
            .filter(|(_, rel, _)| matches!(rel, Rel::Ge | Rel::Eq))
            .count();
        let total = self.n + n_slack + n_art;
        let rhs_col = total;

        let mut t = vec![vec![0.0f64; total + 1]; m];
        let mut basis = vec![usize::MAX; m];
        let mut slack_idx = self.n;
        let mut art_idx = self.n + n_slack;
        let mut art_cols = Vec::new();

        for (r, (coeffs, rel, rhs)) in rows.iter().enumerate() {
            t[r][..self.n].copy_from_slice(coeffs);
            t[r][rhs_col] = *rhs;
            match rel {
                Rel::Le => {
                    t[r][slack_idx] = 1.0;
                    basis[r] = slack_idx;
                    slack_idx += 1;
                }
                Rel::Ge => {
                    t[r][slack_idx] = -1.0;
                    slack_idx += 1;
                    t[r][art_idx] = 1.0;
                    basis[r] = art_idx;
                    art_cols.push(art_idx);
                    art_idx += 1;
                }
                Rel::Eq => {
                    t[r][art_idx] = 1.0;
                    basis[r] = art_idx;
                    art_cols.push(art_idx);
                    art_idx += 1;
                }
            }
        }

        // ---- Phase 1 ----
        if n_art > 0 {
            let mut phase1 = vec![0.0; total];
            for &c in &art_cols {
                phase1[c] = 1.0;
            }
            let v = run_simplex(&mut t, &mut basis, &phase1, rhs_col)?;
            if v > 1e-6 {
                return Err(LpError::Infeasible(v));
            }
            // Drive any remaining artificial out of the basis.
            for r in 0..m {
                if art_cols.contains(&basis[r]) {
                    // Pivot on any non-artificial column with nonzero coeff.
                    if let Some(c) = (0..self.n + n_slack)
                        .find(|&c| t[r][c].abs() > EPS)
                    {
                        pivot(&mut t, &mut basis, r, c, rhs_col);
                    }
                    // If none exists the row is all-zero (redundant); the
                    // artificial stays basic at value 0, which is harmless.
                }
            }
            // Freeze artificial columns at zero for phase 2.
            for r in 0..m {
                for &c in &art_cols {
                    if basis[r] != c {
                        t[r][c] = 0.0;
                    }
                }
            }
        }

        // ---- Phase 2 ----
        let mut full_obj = vec![0.0; total];
        full_obj[..self.n].copy_from_slice(&obj);
        // Artificials must not re-enter: give them a prohibitive cost.
        for &c in &art_cols {
            full_obj[c] = 1e12;
        }
        let v = run_simplex(&mut t, &mut basis, &full_obj, rhs_col)?;

        let mut x = vec![0.0; self.n];
        for (r, &b) in basis.iter().enumerate() {
            if b < self.n {
                x[b] = t[r][rhs_col];
            }
        }
        let value = match self.sense {
            Sense::Minimize => v,
            Sense::Maximize => -v,
        };
        Ok(LpSolution { x, value })
    }
}

/// Optimize `obj` over the current tableau; returns the objective value.
///
/// Reduced costs are kept in an incrementally-updated objective row
/// (recomputing c_j - c_B·B⁻¹A_j from scratch each iteration is O(m·n)
/// and dominated solver time before the perf pass — EXPERIMENTS.md
/// §Perf).
fn run_simplex(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    obj: &[f64],
    rhs_col: usize,
) -> Result<f64, LpError> {
    let m = t.len();
    let total = obj.len();
    let mut stall = 0usize;

    // Initial reduced-cost row (and negative objective value in the
    // rhs slot): z_j = c_j - c_B . B^-1 A_j.
    let mut zrow = vec![0.0f64; rhs_col + 1];
    for j in 0..=rhs_col {
        let mut z = 0.0;
        for r in 0..m {
            z += obj[basis[r]] * t[r][j];
        }
        let c = if j < total { obj[j] } else { 0.0 };
        zrow[j] = c - z;
    }

    for _iter in 0..MAX_ITERS {
        // Entering column: Dantzig (most negative), Bland after stalls.
        let entering = if stall < 64 {
            let mut best = None;
            let mut best_val = -1e-9;
            for (j, &rc) in zrow[..total].iter().enumerate() {
                if rc < best_val {
                    best_val = rc;
                    best = Some(j);
                }
            }
            best
        } else {
            zrow[..total].iter().position(|&rc| rc < -1e-9)
        };
        let Some(e) = entering else {
            // Optimal: zrow's rhs slot carries -objective.
            return Ok(-zrow[rhs_col]);
        };

        // Ratio test (Bland tie-break on basis index for determinism).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for r in 0..m {
            if t[r][e] > EPS {
                let ratio = t[r][rhs_col] / t[r][e];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.is_some_and(|l| basis[r] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(r);
                }
            }
        }
        let Some(l) = leave else {
            return Err(LpError::Unbounded);
        };
        if best_ratio < EPS {
            stall += 1;
        } else {
            stall = 0;
        }
        pivot(t, basis, l, e, rhs_col);
        // Update the reduced-cost row with the (normalized) pivot row.
        let f = zrow[e];
        if f.abs() > 0.0 {
            for j in 0..=rhs_col {
                zrow[j] -= f * t[l][j];
            }
        }
        // Numerical hygiene: the entering column is now basic.
        zrow[e] = 0.0;
    }
    Err(LpError::IterationLimit)
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, rhs_col: usize) {
    let m = t.len();
    let p = t[row][col];
    debug_assert!(p.abs() > EPS);
    for j in 0..=rhs_col {
        t[row][j] /= p;
    }
    for r in 0..m {
        if r != row && t[r][col].abs() > EPS {
            let f = t[r][col];
            for j in 0..=rhs_col {
                t[r][j] -= f * t[row][j];
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn textbook_maximization() {
        // max 3x + 5y, x <= 4, 2y <= 12, 3x + 2y <= 18 -> (2, 6), 36.
        let mut lp = LpProblem::new(2, vec![3.0, 5.0], Sense::Maximize);
        lp.constrain(vec![1.0, 0.0], Rel::Le, 4.0);
        lp.constrain(vec![0.0, 2.0], Rel::Le, 12.0);
        lp.constrain(vec![3.0, 2.0], Rel::Le, 18.0);
        let s = lp.solve().unwrap();
        assert_close(s.value, 36.0);
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 6.0);
    }

    #[test]
    fn minimization_with_ge() {
        // min 2x + 3y, x + y >= 4, x >= 1 -> (4, 0) value 8.
        let mut lp = LpProblem::new(2, vec![2.0, 3.0], Sense::Minimize);
        lp.constrain(vec![1.0, 1.0], Rel::Ge, 4.0);
        lp.constrain(vec![1.0, 0.0], Rel::Ge, 1.0);
        let s = lp.solve().unwrap();
        assert_close(s.value, 8.0);
        assert_close(s.x[0], 4.0);
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + 2y = 6, x - y = 0 -> x = y = 2, value 4.
        let mut lp = LpProblem::new(2, vec![1.0, 1.0], Sense::Minimize);
        lp.constrain(vec![1.0, 2.0], Rel::Eq, 6.0);
        lp.constrain(vec![1.0, -1.0], Rel::Eq, 0.0);
        let s = lp.solve().unwrap();
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 2.0);
        assert_close(s.value, 4.0);
    }

    #[test]
    fn detects_infeasible() {
        // x >= 5 and x <= 2.
        let mut lp = LpProblem::new(1, vec![1.0], Sense::Minimize);
        lp.constrain(vec![1.0], Rel::Ge, 5.0);
        lp.constrain(vec![1.0], Rel::Le, 2.0);
        assert!(matches!(lp.solve(), Err(LpError::Infeasible(_))));
    }

    #[test]
    fn detects_unbounded() {
        // max x with only x >= 1.
        let mut lp = LpProblem::new(1, vec![1.0], Sense::Maximize);
        lp.constrain(vec![1.0], Rel::Ge, 1.0);
        assert_eq!(lp.solve(), Err(LpError::Unbounded));
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2 (i.e. y >= x + 2), min y with x >= 0 -> y = 2.
        let mut lp = LpProblem::new(2, vec![0.0, 1.0], Sense::Minimize);
        lp.constrain(vec![1.0, -1.0], Rel::Le, -2.0);
        let s = lp.solve().unwrap();
        assert_close(s.value, 2.0);
    }

    #[test]
    fn bound_helper() {
        // max x + y with x <= 3, y <= 1.5 via bound().
        let mut lp = LpProblem::new(2, vec![1.0, 1.0], Sense::Maximize);
        lp.bound(0, None, Some(3.0));
        lp.bound(1, None, Some(1.5));
        let s = lp.solve().unwrap();
        assert_close(s.value, 4.5);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degenerate example; must not cycle.
        let mut lp = LpProblem::new(4, vec![-0.75, 150.0, -0.02, 6.0], Sense::Minimize);
        lp.constrain(vec![0.25, -60.0, -0.04, 9.0], Rel::Le, 0.0);
        lp.constrain(vec![0.5, -90.0, -0.02, 3.0], Rel::Le, 0.0);
        lp.constrain(vec![0.0, 0.0, 1.0, 0.0], Rel::Le, 1.0);
        let s = lp.solve().unwrap();
        assert_close(s.value, -0.05);
    }

    #[test]
    fn redundant_equalities_ok() {
        // x + y = 2 stated twice.
        let mut lp = LpProblem::new(2, vec![1.0, 2.0], Sense::Minimize);
        lp.constrain(vec![1.0, 1.0], Rel::Eq, 2.0);
        lp.constrain(vec![1.0, 1.0], Rel::Eq, 2.0);
        let s = lp.solve().unwrap();
        assert_close(s.value, 2.0); // all weight on x
    }
}
