//! Branch-and-bound MILP solver over the simplex LP relaxation.
//!
//! Variables are continuous or binary. Nodes are explored best-first
//! (lowest LP bound for minimization), branching on the most fractional
//! binary; integer-feasible LP solutions update the incumbent, and
//! nodes whose bound cannot beat it are pruned.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::simplex::{LpError, LpProblem, Rel, Sense};

#[derive(Debug, PartialEq)]
pub enum MilpError {
    Infeasible,
    Unbounded,
    NodeLimit,
}

impl std::fmt::Display for MilpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MilpError::Infeasible => write!(f, "MILP is infeasible"),
            MilpError::Unbounded => write!(f, "LP relaxation unbounded"),
            MilpError::NodeLimit => {
                write!(f, "node limit reached without proving optimality")
            }
        }
    }
}

impl std::error::Error for MilpError {}

/// A MILP: minimize/maximize `objective . x` with linear constraints,
/// `x >= 0`, and a subset of variables restricted to {0, 1}.
#[derive(Debug, Clone)]
pub struct MilpProblem {
    pub n: usize,
    pub objective: Vec<f64>,
    pub sense: Sense,
    constraints: Vec<(Vec<f64>, Rel, f64)>,
    binary: Vec<bool>,
    /// Safety valve for pathological instances.
    pub max_nodes: usize,
    /// Optional known upper bound on the optimum (minimize sense, in
    /// the user's sense for maximize). Branch-and-bound prunes against
    /// it from node one — a warm start from a cheap heuristic/DP cuts
    /// the tree dramatically (EXPERIMENTS.md §Perf).
    pub initial_upper_bound: Option<f64>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MilpSolution {
    pub x: Vec<f64>,
    pub value: f64,
    /// Branch-and-bound nodes explored (diagnostics / Figure 12).
    pub nodes: usize,
}

const INT_EPS: f64 = 1e-6;

struct Node {
    /// LP bound (in minimize-internal sense).
    bound: f64,
    /// (var, forced value) decisions along this branch.
    fixes: Vec<(usize, f64)>,
    /// The relaxation solution at this node.
    relax: super::simplex::LpSolution,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: reverse so the *lowest* bound pops first.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

impl MilpProblem {
    pub fn new(n: usize, objective: Vec<f64>, sense: Sense) -> MilpProblem {
        assert_eq!(objective.len(), n);
        MilpProblem {
            n,
            objective,
            sense,
            constraints: Vec::new(),
            binary: vec![false; n],
            max_nodes: 200_000,
            initial_upper_bound: None,
        }
    }

    pub fn constrain(&mut self, coeffs: Vec<f64>, rel: Rel, rhs: f64) {
        assert_eq!(coeffs.len(), self.n);
        self.constraints.push((coeffs, rel, rhs));
    }

    pub fn set_binary(&mut self, i: usize) {
        self.binary[i] = true;
    }

    /// Solve by best-first branch-and-bound.
    pub fn solve(&self) -> Result<MilpSolution, MilpError> {
        // Work internally in minimize sense.
        let internal_obj: Vec<f64> = match self.sense {
            Sense::Minimize => self.objective.clone(),
            Sense::Maximize => self.objective.iter().map(|c| -c).collect(),
        };

        let solve_relaxation = |fixes: &[(usize, f64)]| -> Result<_, LpError> {
            let mut lp = LpProblem::new(self.n, internal_obj.clone(), Sense::Minimize);
            for (coeffs, rel, rhs) in &self.constraints {
                lp.constrain(coeffs.clone(), *rel, *rhs);
            }
            // Binary relaxation: 0 <= x <= 1.
            for i in 0..self.n {
                if self.binary[i] {
                    lp.bound(i, None, Some(1.0));
                }
            }
            for &(i, v) in fixes {
                let mut c = vec![0.0; self.n];
                c[i] = 1.0;
                lp.constrain(c, Rel::Eq, v);
            }
            lp.solve()
        };

        let mut heap: BinaryHeap<Node> = BinaryHeap::new();
        match solve_relaxation(&[]) {
            Ok(sol) => heap.push(Node { bound: sol.value, fixes: Vec::new(), relax: sol }),
            Err(LpError::Infeasible(_)) => return Err(MilpError::Infeasible),
            Err(LpError::Unbounded) => return Err(MilpError::Unbounded),
            Err(LpError::IterationLimit) => return Err(MilpError::NodeLimit),
        }

        let mut incumbent: Option<MilpSolution> = None;
        // Warm-start bound (slightly relaxed so the true optimum is
        // never pruned by floating-point slack).
        let mut best_val = match (self.initial_upper_bound, self.sense) {
            (Some(ub), Sense::Minimize) => ub + 1e-6 * ub.abs().max(1.0),
            (Some(ub), Sense::Maximize) => -ub + 1e-6 * ub.abs().max(1.0),
            (None, _) => f64::INFINITY,
        };
        let mut nodes = 0usize;

        while let Some(node) = heap.pop() {
            let relax = &node.relax;
            nodes += 1;
            if nodes > self.max_nodes {
                return Err(MilpError::NodeLimit);
            }
            if node.bound >= best_val - 1e-9 {
                continue; // pruned
            }

            // Most fractional binary variable.
            let frac = (0..self.n)
                .filter(|&i| self.binary[i])
                .map(|i| (i, (relax.x[i] - relax.x[i].round()).abs()))
                .filter(|(_, f)| *f > INT_EPS)
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

            match frac {
                None => {
                    // Integer feasible.
                    if relax.value < best_val {
                        best_val = relax.value;
                        incumbent = Some(MilpSolution {
                            x: relax.x.clone(),
                            value: relax.value,
                            nodes,
                        });
                    }
                }
                Some((i, _)) => {
                    for v in [0.0, 1.0] {
                        let mut fixes = node.fixes.clone();
                        fixes.push((i, v));
                        match solve_relaxation(&fixes) {
                            Ok(sol) => {
                                if sol.value < best_val - 1e-9 {
                                    heap.push(Node { bound: sol.value, fixes, relax: sol });
                                }
                            }
                            Err(LpError::Infeasible(_)) => {}
                            Err(LpError::Unbounded) => return Err(MilpError::Unbounded),
                            Err(LpError::IterationLimit) => {}
                        }
                    }
                }
            }
        }

        match incumbent {
            Some(mut s) => {
                // Round binaries exactly and report in the user's sense.
                for i in 0..self.n {
                    if self.binary[i] {
                        s.x[i] = s.x[i].round();
                    }
                }
                s.nodes = nodes;
                if self.sense == Sense::Maximize {
                    s.value = -s.value;
                }
                Ok(s)
            }
            None => Err(MilpError::Infeasible),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-5, "{a} != {b}");
    }

    #[test]
    fn pure_lp_passthrough() {
        let mut p = MilpProblem::new(2, vec![3.0, 5.0], Sense::Maximize);
        p.constrain(vec![1.0, 0.0], Rel::Le, 4.0);
        p.constrain(vec![0.0, 2.0], Rel::Le, 12.0);
        p.constrain(vec![3.0, 2.0], Rel::Le, 18.0);
        let s = p.solve().unwrap();
        assert_close(s.value, 36.0);
    }

    #[test]
    fn knapsack() {
        // max 10a + 13b + 7c, 3a + 4b + 2c <= 6, binary -> a + c = 17? \
        // options: a+b (7 wt) no; a+c wt 5 val 17; b+c wt 6 val 20. -> 20.
        let mut p = MilpProblem::new(3, vec![10.0, 13.0, 7.0], Sense::Maximize);
        p.constrain(vec![3.0, 4.0, 2.0], Rel::Le, 6.0);
        for i in 0..3 {
            p.set_binary(i);
        }
        let s = p.solve().unwrap();
        assert_close(s.value, 20.0);
        assert_close(s.x[1], 1.0);
        assert_close(s.x[2], 1.0);
    }

    #[test]
    fn assignment_with_equality_budget() {
        // Mini §3.2 shape: 2 "models", allocations f in {1,2,3} with
        // latencies; pick one per model, total = 4, min max-latency via
        // auxiliary L variable (var 6).
        // model 0 latencies: f1=9, f2=5, f3=2; model 1: f1=8, f2=4, f3=3.
        let n = 7;
        let mut obj = vec![0.0; n];
        obj[6] = 1.0;
        let mut p = MilpProblem::new(n, obj, Sense::Minimize);
        // One allocation per model.
        p.constrain(vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0], Rel::Eq, 1.0);
        p.constrain(vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.0], Rel::Eq, 1.0);
        // GPU budget: 1*x01 + 2*x02 + 3*x03 + ... = 4.
        p.constrain(vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0, 0.0], Rel::Eq, 4.0);
        // L >= selected latency.
        p.constrain(vec![9.0, 5.0, 2.0, 0.0, 0.0, 0.0, -1.0], Rel::Le, 0.0);
        p.constrain(vec![0.0, 0.0, 0.0, 8.0, 4.0, 3.0, -1.0], Rel::Le, 0.0);
        for i in 0..6 {
            p.set_binary(i);
        }
        let s = p.solve().unwrap();
        // Options: (f=1,f=3): max(9,3)=9; (f=2,f=2): max(5,4)=5;
        // (f=3,f=1): max(2,8)=8. Best = 5.
        assert_close(s.value, 5.0);
        assert_close(s.x[1], 1.0);
        assert_close(s.x[4], 1.0);
    }

    #[test]
    fn infeasible_budget() {
        let mut p = MilpProblem::new(2, vec![1.0, 1.0], Sense::Minimize);
        p.constrain(vec![1.0, 0.0], Rel::Eq, 1.0);
        p.constrain(vec![0.0, 1.0], Rel::Eq, 1.0);
        p.constrain(vec![1.0, 1.0], Rel::Le, 1.0);
        p.set_binary(0);
        p.set_binary(1);
        assert_eq!(p.solve(), Err(MilpError::Infeasible));
    }

    #[test]
    fn fractional_lp_vs_integer_gap() {
        // max x1 + x2, 2x1 + 2x2 <= 3, binary: LP gives 1.5, MILP 1.0.
        let mut p = MilpProblem::new(2, vec![1.0, 1.0], Sense::Maximize);
        p.constrain(vec![2.0, 2.0], Rel::Le, 3.0);
        p.set_binary(0);
        p.set_binary(1);
        let s = p.solve().unwrap();
        assert_close(s.value, 1.0);
    }

    #[test]
    fn bigger_random_knapsack_agrees_with_dp() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for trial in 0..10 {
            let n = 12;
            let values: Vec<f64> = (0..n).map(|_| rng.range_i64(1, 30) as f64).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range_i64(1, 12) as f64).collect();
            let cap = 30.0;
            let mut p = MilpProblem::new(n, values.clone(), Sense::Maximize);
            p.constrain(weights.clone(), Rel::Le, cap);
            for i in 0..n {
                p.set_binary(i);
            }
            let milp = p.solve().unwrap();
            // Exact DP over integer weights.
            let capi = cap as usize;
            let mut dp = vec![0.0f64; capi + 1];
            for i in 0..n {
                let w = weights[i] as usize;
                for c in (w..=capi).rev() {
                    dp[c] = dp[c].max(dp[c - w] + values[i]);
                }
            }
            assert!(
                (milp.value - dp[capi]).abs() < 1e-6,
                "trial {trial}: milp {} dp {}",
                milp.value,
                dp[capi]
            );
        }
    }
}
