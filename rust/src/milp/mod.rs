//! Mixed-integer linear programming, from scratch.
//!
//! The inner level of Cascadia's bi-level scheduler (§3.2) is a MILP:
//! binary assignment variables `x_{i,f}` select one GPU allocation per
//! model type, a budget equality ties them to the cluster size, and a
//! continuous `L` upper-bounds every selected latency. No LP/MILP
//! library exists in the offline crate set, so this module implements
//! the substrate:
//!
//! * [`simplex`] — two-phase dense-tableau simplex with Bland's rule,
//!   supporting ≤ / ≥ / = rows and minimize/maximize;
//! * [`solver`] — branch-and-bound over binary variables with
//!   best-first node selection and LP-bound pruning.
//!
//! The specific §3.2 structure also admits an exact dynamic-programming
//! solution ([`crate::sched::inner`] uses it as a cross-check); property
//! tests assert the two agree, which doubles as a correctness proof of
//! this solver on that family.

pub mod simplex;
pub mod solver;

pub use simplex::{LpError, LpProblem, LpSolution, Rel};
pub use solver::{MilpProblem, MilpSolution};
