//! Cascadia launcher.
//!
//! Subcommands:
//!   schedule   run the bi-level scheduler on a config, print the plan
//!   sweep      print the full Pareto front for a config
//!   simulate   schedule + simulate on a held-out trace, print metrics
//!   baselines  compare the three systems on one scenario
//!   trace      generate a workload trace CSV
//!   replay     drift replay: frozen vs adaptive (monitor -> re-schedule
//!              -> hot-swap) serving of a phase-shift trace
//!   bench      calibrated serving benchmark: batch-lockstep vs the
//!              continuous-batching engine; writes BENCH_serving.json
//!
//! `--config path.json` loads an ExperimentConfig; all fields also have
//! CLI overrides (--cascade, --gpus, --trace, --rate, --quality, ...).
//! Live serving of the real tiny-tier cascade lives in
//! `examples/e2e_serving.rs` (requires `make artifacts`).

use anyhow::{bail, Context, Result};
use cascadia::config::ExperimentConfig;
use cascadia::harness::Scenario;
use cascadia::report::{fmt_secs, Table};
use cascadia::router::{PolicyKind, PolicySpec, RoutingPolicy};
use cascadia::sched::outer::select_plan;
use cascadia::sched::plan::CascadePlan;
use cascadia::util::cli::Args;
use cascadia::workload::generate;

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get("cascade") {
        cfg.cascade_name = v.to_string();
    }
    if let Some(v) = args.get("gpus") {
        cfg.n_gpus = v.parse().context("--gpus")?;
    }
    if let Some(v) = args.get("trace") {
        cfg.trace_index = v.parse().context("--trace")?;
    }
    if let Some(v) = args.get("rate") {
        cfg.rate = v.parse().context("--rate")?;
    }
    if let Some(v) = args.get("quality") {
        cfg.quality_requirement = v.parse().context("--quality")?;
    }
    if let Some(v) = args.get("n") {
        cfg.n_requests = v.parse().context("--n")?;
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    if let Some(v) = args.get("policy") {
        cfg.policy_kind = PolicyKind::parse(v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn scenario_of(cfg: &ExperimentConfig) -> Scenario {
    Scenario::new(
        cfg.cascade(),
        cfg.n_gpus,
        cfg.trace_index,
        cfg.rate,
        cfg.n_requests,
        cfg.seed,
    )
}

/// Diagnostics go to stderr so `cascadia schedule ... > plan.json`
/// captures a pure plan artifact that `cascadia serve --plan` loads.
fn cmd_schedule(cfg: &ExperimentConfig) -> Result<()> {
    let scenario = scenario_of(cfg);
    let opts = cfg.outer_options();
    let (sweep, secs) = scenario.schedule(&opts)?;
    let plan = select_plan(&sweep, cfg.quality_requirement)
        .with_context(|| format!("no plan meets quality {}", cfg.quality_requirement))?;
    eprintln!(
        "scheduled in {secs:.2}s ({} candidates, {} Pareto-optimal)",
        sweep.explored.len(),
        sweep.pareto.len()
    );
    eprintln!("{}", plan.summary());
    println!("{}", plan.to_json());
    Ok(())
}

fn cmd_sweep(cfg: &ExperimentConfig) -> Result<()> {
    let scenario = scenario_of(cfg);
    let opts = cfg.outer_options();
    let (sweep, secs) = scenario.schedule(&opts)?;
    let mut t = Table::new(
        &format!(
            "Pareto front ({secs:.2}s, utopia L={:.2}s Q={:.1})",
            sweep.utopia.0, sweep.utopia.1
        ),
        &["latency(s)", "quality", "policy", "allocation"],
    );
    for p in &sweep.pareto {
        t.row(vec![
            format!("{:.3}", p.latency),
            format!("{:.2}", p.quality),
            p.plan.policy.label(),
            format!("{:?}", p.plan.tiers.iter().map(|x| x.gpus).collect::<Vec<_>>()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_simulate(cfg: &ExperimentConfig) -> Result<()> {
    let scenario = scenario_of(cfg);
    let opts = cfg.outer_options();
    let plan = scenario.cascadia_plan(cfg.quality_requirement, &opts)?;
    println!("plan: {}", plan.summary());
    let sim = scenario.evaluate(&plan)?;
    let mut t = Table::new("simulation (held-out trace)", &["metric", "value"]);
    t.row(vec!["requests".into(), sim.e2e_latencies.len().to_string()]);
    t.row(vec!["mean latency".into(), fmt_secs(sim.mean())]);
    t.row(vec!["p95 latency".into(), fmt_secs(sim.p95())]);
    t.row(vec!["throughput".into(), format!("{:.2} req/s", sim.throughput_rps)]);
    t.row(vec!["quality".into(), format!("{:.1}", sim.quality)]);
    for (i, r) in plan.tiers.iter().enumerate() {
        t.row(vec![
            format!("tier {} ({})", i + 1, r.model_name),
            format!(
                "f={} {}  p={:.0}%",
                r.gpus,
                r.strategy.as_ref().map(|s| s.label()).unwrap_or_else(|| "-".into()),
                r.processing_ratio * 100.0
            ),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `cascadia trace`: workload CSV by default. `--export chrome` serves
/// the workload through the traced paged DES and writes Chrome
/// trace-event JSON (loadable in Perfetto / chrome://tracing);
/// `--diff` replays one trace through both the paged DES and a real
/// `EngineCore` and reports the first per-request timeline divergence
/// (non-zero exit on any).
fn cmd_trace(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    if args.flag("diff") {
        return cmd_trace_diff(&cfg);
    }
    match args.get("export") {
        None => cmd_trace_csv(&cfg, &args.str_or("out", "results/trace.csv")),
        Some(fmt) if fmt == "chrome" => cmd_trace_chrome(
            &cfg,
            args.usize_or("replicas", 2)?,
            &args.str_or("out", "results/trace_chrome.json"),
        ),
        Some(other) => bail!("unknown --export format '{other}' (expected: chrome)"),
    }
}

fn cmd_trace_csv(cfg: &ExperimentConfig, out: &str) -> Result<()> {
    let reqs = generate(&cfg.trace_spec(), cfg.n_requests, cfg.seed);
    let mut t = Table::new("", &["id", "arrival", "input_tokens", "output_tokens", "complexity"]);
    for r in &reqs {
        t.row(vec![
            r.id.to_string(),
            format!("{:.3}", r.arrival),
            r.input_tokens.to_string(),
            r.output_tokens.to_string(),
            format!("{:.3}", r.complexity),
        ]);
    }
    t.write_csv(out)?;
    println!("wrote {} requests to {out}", reqs.len());
    Ok(())
}

/// The configured workload as a paged-DES trace plus a replica sized
/// for it under the scheduler's own cost model. `zero_arrivals` folds
/// every arrival to t=0 — the all-at-once regime where DES ticks and
/// live engine steps align by construction (what `--diff` compares).
fn des_trace_inputs(
    cfg: &ExperimentConfig,
    zero_arrivals: bool,
) -> (cascadia::perf::ReplicaModel, Vec<cascadia::sim::SimRequest>) {
    use cascadia::sim::SimRequest;
    let reqs = generate(&cfg.trace_spec(), cfg.n_requests, cfg.seed);
    let trace: Vec<SimRequest> = reqs
        .iter()
        .map(|r| {
            SimRequest::new(
                if zero_arrivals { 0.0 } else { r.arrival },
                r.input_tokens.clamp(2, 4096),
                r.output_tokens.clamp(1, 256),
            )
        })
        .collect();
    let avg_ctx = trace
        .iter()
        .map(|r| (r.input_tokens + r.output_tokens) as f64)
        .sum::<f64>()
        / trace.len().max(1) as f64;
    let cascade = cfg.cascade();
    let cluster = cascadia::cluster::ClusterSpec::with_gpus(cfg.n_gpus);
    let rm =
        cascadia::perf::ReplicaModel::new(&cascade[0], &cluster, 1, 1, avg_ctx.max(64.0));
    (rm, trace)
}

fn cmd_trace_chrome(cfg: &ExperimentConfig, replicas: usize, out: &str) -> Result<()> {
    use cascadia::obs::{chrome_trace, TraceRecorder};
    use cascadia::sim::simulate_paged_traced;

    let (rm, trace) = des_trace_inputs(cfg, false);
    let pool = vec![rm; replicas.max(1)];
    let rec = TraceRecorder::new(pool.len(), 1 << 18);
    let outcome = simulate_paged_traced(&pool, &trace, 16, usize::MAX, false, &rec);
    let events = rec.snapshot();
    let json = chrome_trace(&events);
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, format!("{json}\n")).with_context(|| format!("writing {out}"))?;
    println!(
        "wrote {} trace events ({} dropped) for {} requests (DES p95 {:.2}s) to {out}",
        events.len(),
        rec.dropped_events(),
        trace.len(),
        outcome.p95(),
    );
    Ok(())
}

/// Deterministic token-by-token backend for the `--diff` engine drive.
struct DiffStep;

impl cascadia::engine::StepBackend for DiffStep {
    fn prefill_chunk(
        &mut self,
        seq: cascadia::engine::SeqId,
        _chunk: &[i32],
        last: bool,
    ) -> Result<Option<i32>> {
        Ok(last.then_some(seq as i32))
    }
    fn decode(&mut self, seqs: &[cascadia::engine::SeqId]) -> Result<Vec<i32>> {
        Ok(seqs.iter().map(|&s| s as i32).collect())
    }
    fn release(&mut self, _seq: cascadia::engine::SeqId) {}
}

/// The `--diff` harness: the same all-at-once workload served by the
/// traced paged DES and by a real `EngineCore` twin, returning both
/// event timelines. Shared by `cascadia trace --diff` and the
/// DES-vs-live attribution-identity test.
fn diff_harness_traces(
    cfg: &ExperimentConfig,
) -> Result<(Vec<cascadia::obs::Event>, Vec<cascadia::obs::Event>)> {
    use std::sync::Arc;

    use cascadia::engine::{EngineConfig, EngineCore, PreemptionConfig};
    use cascadia::obs::{EngineTracer, TraceRecorder};
    use cascadia::sim::simulate_paged_traced;

    let (rm, mut trace) = des_trace_inputs(cfg, true);
    trace.truncate(64); // the diff is per-request; 64 spans suffice
    let des_rec = TraceRecorder::new(1, 1 << 18);
    let _ = simulate_paged_traced(&[rm.clone()], &trace, 16, usize::MAX, false, &des_rec);

    let engine_cfg = EngineConfig {
        pool_pages: rm.kv_pages_total(16),
        page_tokens: 16,
        max_running: rm.max_batch.max(1),
        prefill_chunk: usize::MAX,
        share_prefixes: false,
        preemption: PreemptionConfig::default(),
    };
    let live_rec = Arc::new(TraceRecorder::new(1, 1 << 18));
    let mut eng: EngineCore<usize> = EngineCore::new(Box::new(DiffStep), engine_cfg);
    eng.set_tracer(Some(EngineTracer::standalone(Arc::clone(&live_rec))));
    let prompt_of = |r: &cascadia::sim::SimRequest| vec![7i32; r.input_tokens.max(1) as usize];
    // Mirror the DES arrival semantics: request 0 alone in iteration 1,
    // the rest visible from iteration 2.
    eng.submit(0, prompt_of(&trace[0]), trace[0].output_tokens.max(1) as usize);
    let mut first = true;
    let mut ticks = 0u64;
    while !eng.is_idle() {
        ticks += 1;
        if ticks > 1_000_000 {
            bail!("engine failed to drain the diff trace within 1M iterations");
        }
        eng.step()?;
        if first {
            for (i, r) in trace.iter().enumerate().skip(1) {
                eng.submit(i, prompt_of(r), r.output_tokens.max(1) as usize);
            }
            first = false;
        }
    }
    Ok((des_rec.snapshot(), live_rec.snapshot()))
}

/// The exit verdict `cascadia trace --diff` applies to a diff report:
/// `Ok` (with the printed line) on equivalence, `Err` carrying the
/// first divergence otherwise — so the shell exit code is the contract.
fn trace_diff_verdict(report: &cascadia::obs::DiffReport) -> Result<String> {
    if report.is_equivalent() {
        return Ok("timelines are equivalent: zero divergence".to_string());
    }
    let first = match report.first_divergence() {
        Some(d) => format!("first divergence: {d}"),
        None => format!(
            "request sets differ: only in DES {:?}, only live {:?}",
            report.only_left, report.only_right
        ),
    };
    bail!(
        "{first} — DES and live timelines diverge ({} divergences)",
        report.divergences.len()
    )
}

fn cmd_trace_diff(cfg: &ExperimentConfig) -> Result<()> {
    use cascadia::obs::diff_timelines;

    let (left, right) = diff_harness_traces(cfg)?;
    let report = diff_timelines(&left, &right);
    println!(
        "DES events: {} | live events: {} | requests compared: {}",
        report.events_left, report.events_right, report.requests_compared
    );
    let msg = trace_diff_verdict(&report)?;
    println!("{msg}");
    Ok(())
}

/// Drift replay (§4.4): serve a phase-shift trace twice — frozen at
/// the startup plan and with the full adaptation loop — and report
/// per-phase SLO attainment/quality plus the loop counters.
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args.get("config").context(
        "replay requires --config (see examples/configs/drift_replay.json)",
    )?;
    let cfg = cascadia::adapt::ReplayConfig::load(path)?;

    // Optional observability artifacts: a Chrome trace-event timeline
    // and a Prometheus scrape snapshot of the ADAPTIVE run, plus (via
    // --trace-frozen-out) the FROZEN control run's timeline so the two
    // can be diffed with the `cascadia trace` tooling.
    let trace_out = args.get("trace-out");
    let metrics_out = args.get("metrics-out");
    let frozen_out = args.get("trace-frozen-out");
    let n_tiers = cascadia::models::cascade_by_name(&cfg.cascade_name)
        .map(|c| c.len())
        .unwrap_or(2);
    let telemetry = (trace_out.is_some() || metrics_out.is_some())
        .then(|| cascadia::coordinator::ServeTelemetry::for_tiers(n_tiers));
    let frozen_telemetry =
        frozen_out.map(|_| cascadia::coordinator::ServeTelemetry::for_tiers(n_tiers));
    let report =
        cascadia::adapt::run_replay_with_obs(&cfg, telemetry.clone(), frozen_telemetry.clone())?;
    if let Some(tm) = &telemetry {
        if let Some(out) = trace_out {
            let json = cascadia::obs::chrome_trace(&tm.recorder.snapshot());
            std::fs::write(out, format!("{json}\n"))
                .with_context(|| format!("writing {out}"))?;
            println!(
                "wrote Chrome trace ({} events, {} dropped) to {out}",
                tm.recorder.n_events(),
                tm.recorder.dropped_events()
            );
        }
        if let Some(out) = metrics_out {
            std::fs::write(out, tm.registry.render_prometheus())
                .with_context(|| format!("writing {out}"))?;
            println!("wrote Prometheus metrics snapshot to {out}");
        }
    }
    if let (Some(tm), Some(out)) = (&frozen_telemetry, frozen_out) {
        let json = cascadia::obs::chrome_trace(&tm.recorder.snapshot());
        std::fs::write(out, format!("{json}\n")).with_context(|| format!("writing {out}"))?;
        println!(
            "wrote frozen-run Chrome trace ({} events, {} dropped) to {out}",
            tm.recorder.n_events(),
            tm.recorder.dropped_events()
        );
    }

    println!("initial plan : {}", report.initial_plan);
    match &report.final_plan {
        Some(p) => println!("final plan   : {p}"),
        None => println!("final plan   : (no re-schedule fired)"),
    }
    let mut t = Table::new(
        &format!("drift replay (SLO = {:.0}s e2e)", report.slo_seconds),
        &[
            "phase",
            "requests",
            "frozen SLO",
            "adaptive SLO",
            "frozen Q",
            "adaptive Q",
            "adaptive p95(s)",
        ],
    );
    for (f, a) in report.frozen.phases.iter().zip(&report.adaptive.phases) {
        t.row(vec![
            f.label.clone(),
            f.requests.to_string(),
            format!("{:.1}%", f.slo_attainment * 100.0),
            format!("{:.1}%", a.slo_attainment * 100.0),
            format!("{:.1}", f.mean_quality),
            format!("{:.1}", a.mean_quality),
            format!("{:.2}", a.latency.p95),
        ]);
    }
    t.row(vec![
        "overall".into(),
        report.adaptive.served.to_string(),
        format!("{:.1}%", report.frozen.overall_attainment * 100.0),
        format!("{:.1}%", report.adaptive.overall_attainment * 100.0),
        format!("{:.1}", report.frozen.mean_quality),
        format!("{:.1}", report.adaptive.mean_quality),
        String::new(),
    ]);
    print!("{}", t.render());
    // Per-tier queue + engine telemetry of the adaptive run: what the
    // batcher always tracked but never reported, and the paged
    // engine's occupancy/preemption counters.
    let mut tele = Table::new(
        "tier telemetry (adaptive run)",
        &["tier", "queue peak", "mean wait(s)", "pages peak/pool", "preempt", "iters"],
    );
    for (t, q) in report.adaptive.queue.iter().enumerate() {
        let e = report.adaptive.engine.get(t).copied().unwrap_or_default();
        tele.row(vec![
            format!("{t}"),
            q.peak_depth.to_string(),
            format!("{:.2}", q.mean_wait_s),
            if e.pool_pages > 0 {
                format!("{}/{}", e.peak_pages, e.pool_pages)
            } else {
                "-".into()
            },
            e.preemptions.to_string(),
            e.iterations.to_string(),
        ]);
    }
    print!("{}", tele.render());
    for (t, e) in report.adaptive.engine.iter().enumerate() {
        // Compare against the largest budget in force during the run:
        // a pool-shrinking hot-swap legitimately leaves peak occupancy
        // above the FINAL budget while old admissions drain.
        if e.peak_pool_pages > 0 && e.peak_pages > e.peak_pool_pages {
            bail!("tier {t}: page occupancy {} exceeded the pool budget {}",
                  e.peak_pages, e.peak_pool_pages);
        }
    }
    println!(
        "adaptation: {} slo_breaches={} | dropped: frozen {} adaptive {}",
        report.adaptive.counters,
        report.adaptive.slo_breaches,
        report.frozen.dropped,
        report.adaptive.dropped
    );
    if report.adaptive.dropped > 0 || report.frozen.dropped > 0 {
        bail!("requests were dropped — the hot-swap contract is broken");
    }
    if report.adaptive.counters.reschedules == 0 {
        bail!("no re-schedule fired — drift was not detected");
    }
    if report.adaptive.counters.hot_swaps == 0 {
        bail!(
            "a plan was re-scheduled but never hot-swapped into the serving loop \
             (re-schedule finished after serving ended?)"
        );
    }
    println!(
        "adaptation win: {}",
        if report.adaptation_win() { "yes (adaptive beats frozen on SLO attainment)" } else { "no" }
    );
    Ok(())
}

/// `cascadia profile`: fold a request-lifecycle event stream into the
/// per-request phase-attribution waterfall and per-tier health report.
/// Source is either the traced paged DES on the configured workload
/// (default) or an adaptive drift replay with live telemetry
/// (`--replay cfg.json`) — same `cascadia.profile.v1` schema either
/// way. `--out` writes the JSON document, `--metrics-out` (replay
/// source only) a Prometheus snapshot, `--slo SECS` enables SLO
/// attainment / burn-rate evaluation and alerts.
fn cmd_profile(args: &Args) -> Result<()> {
    use cascadia::obs::{ProfileAggregator, ProfileConfig, TraceRecorder};

    let slo_s = match args.get("slo") {
        Some(v) => Some(v.parse::<f64>().context("--slo")?),
        None => None,
    };
    let (events, dropped, registry) = if let Some(path) = args.get("replay") {
        let cfg = cascadia::adapt::ReplayConfig::load(path)?;
        let n_tiers = cascadia::models::cascade_by_name(&cfg.cascade_name)
            .map(|c| c.len())
            .unwrap_or(2);
        let telemetry = cascadia::coordinator::ServeTelemetry::for_tiers(n_tiers);
        let _ = cascadia::adapt::run_replay_with_obs(&cfg, Some(telemetry.clone()), None)?;
        cascadia::obs::export_recorder_health(&telemetry.recorder, &telemetry.registry);
        (
            telemetry.recorder.snapshot(),
            telemetry.recorder.dropped_events(),
            Some(telemetry.registry.clone()),
        )
    } else {
        let cfg = load_config(args)?;
        let (rm, trace) = des_trace_inputs(&cfg, false);
        let pool = vec![rm; args.usize_or("replicas", 2)?.max(1)];
        let rec = TraceRecorder::new(pool.len(), 1 << 18);
        let _ = cascadia::sim::simulate_paged_traced(&pool, &trace, 16, usize::MAX, false, &rec);
        (rec.snapshot(), rec.dropped_events(), None)
    };
    let cfg = ProfileConfig { slo_s, ..Default::default() };
    let mut agg = ProfileAggregator::fold(cfg, &events);
    let report = agg.report(dropped);
    print!("{}", report.render());
    if let Some(out) = args.get("out") {
        std::fs::write(out, format!("{}\n", report.to_json()))
            .with_context(|| format!("writing {out}"))?;
        println!("wrote profile JSON to {out}");
    }
    if let Some(out) = args.get("metrics-out") {
        let reg = registry
            .context("--metrics-out requires --replay (the DES source has no registry)")?;
        std::fs::write(out, reg.render_prometheus())
            .with_context(|| format!("writing {out}"))?;
        println!("wrote Prometheus metrics snapshot to {out}");
    }
    Ok(())
}

/// One blocking HTTP/1.0 GET against the serving front-end's scrape
/// port (std-only — the same trick Prometheus plays on it).
fn http_get(addr: &str, path: &str) -> Result<String> {
    use std::io::{Read as _, Write as _};

    let mut s = std::net::TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    write!(s, "GET {path} HTTP/1.0\r\n\r\n")?;
    let mut response = String::new();
    s.read_to_string(&mut response)?;
    if !response.starts_with("HTTP/1.0 200") {
        bail!(
            "GET {path} on {addr}: {}",
            response.lines().next().unwrap_or("(no response)")
        );
    }
    Ok(response.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

/// One `cascadia top` frame from a `/profile` JSON document and a
/// `/metrics` Prometheus snapshot (either may be absent).
fn render_top_frame(profile: Option<&cascadia::util::json::Json>, metrics: &str) -> String {
    let mut out = String::new();
    if let Some(p) = profile {
        let n = |key: &str| p.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        let e2e = p.get("e2e");
        let pct = |k: &str| {
            e2e.and_then(|o| o.get(k)).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
        };
        out.push_str(&format!(
            "requests {:.0} ({:.0} open) | e2e p50 {:.2}s p95 {:.2}s | span {:.1}s | \
             hot-swaps {:.0} | events {:.0} ({:.0} dropped)\n",
            n("requests"),
            n("open_requests"),
            pct("p50_s"),
            pct("p95_s"),
            n("trace_span_s"),
            n("hot_swaps"),
            n("events"),
            n("dropped_events"),
        ));
        let mut t = Table::new(
            "tier health",
            &["tier", "done", "esc out", "queue", "slope/s", "busy", "att 5m/1h", "burn", "p95(s)"],
        );
        if let Some(tiers) = p.get("tiers").and_then(|v| v.as_arr().ok()) {
            for tier in tiers {
                let g = |k: &str| tier.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
                t.row(vec![
                    format!("{:.0}", g("tier")),
                    format!("{:.0}", g("completed")),
                    format!("{:.0}", g("escalated_out")),
                    format!("{:.0}", g("queue_depth")),
                    format!("{:+.2}", g("queue_slope_per_s")),
                    format!("{:.0}%", g("busy_frac") * 100.0),
                    format!("{:.0}%/{:.0}%", g("attainment_short") * 100.0, g("attainment_long") * 100.0),
                    format!("{:.2}", g("burn_short")),
                    format!("{:.2}", g("window_p95_s")),
                ]);
            }
        }
        out.push_str(&t.render());
        if let Some(alerts) = p.get("alerts").and_then(|v| v.as_arr().ok()) {
            for a in alerts {
                let s = |k: &str| {
                    a.get(k).and_then(|v| v.as_str().ok()).unwrap_or_default().to_string()
                };
                out.push_str(&format!(
                    "ALERT [{}] {}: {}\n",
                    s("severity"),
                    s("kind"),
                    s("evidence")
                ));
            }
        }
    }
    // The scrape series worth eyeballing live; histograms stay out.
    for line in metrics.lines() {
        if line.starts_with("cascadia_requests_")
            || line.starts_with("cascadia_escalations_total")
            || line.starts_with("cascadia_trace_")
        {
            out.push_str(line);
            out.push('\n');
        }
    }
    out
}

/// `cascadia top`: terminal dashboard over a live front-end — polls
/// `GET /profile` + `GET /metrics` on `--addr` every `--interval`
/// seconds; `--once` renders a single frame and exits. Offline mode
/// (`--profile-file` / `--metrics-file`) renders captured snapshots
/// instead, no server needed.
fn cmd_top(args: &Args) -> Result<()> {
    use cascadia::util::json::Json;

    let profile_file = args.get("profile-file");
    let metrics_file = args.get("metrics-file");
    if profile_file.is_some() || metrics_file.is_some() {
        let metrics = match metrics_file {
            Some(p) => std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
            None => String::new(),
        };
        let profile = match profile_file {
            Some(p) => {
                let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
                Some(Json::parse(&text)?)
            }
            None => None,
        };
        print!("{}", render_top_frame(profile.as_ref(), &metrics));
        return Ok(());
    }
    let addr = args.str_or("addr", "127.0.0.1:8741");
    let once = args.flag("once");
    let interval = args.f64_or("interval", 2.0)?;
    loop {
        let profile = Json::parse(&http_get(&addr, "/profile")?)?;
        let metrics = http_get(&addr, "/metrics")?;
        if !once {
            // ANSI clear + home between frames.
            print!("\x1b[2J\x1b[H");
        }
        print!("{}", render_top_frame(Some(&profile), &metrics));
        if once {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval.max(0.2)));
    }
}

fn cmd_baselines(cfg: &ExperimentConfig) -> Result<()> {
    let scenario = scenario_of(cfg);
    let opts = cfg.outer_options();
    let mut t = Table::new(
        "three systems on one scenario",
        &["system", "p95(s)", "throughput", "quality"],
    );
    let plans: Vec<(&str, anyhow::Result<_>)> = vec![
        ("cascadia", scenario.cascadia_plan(cfg.quality_requirement, &opts)),
        ("standalone", scenario.standalone_plan(cfg.quality_requirement)),
        ("cascadeserve", scenario.cascade_serve_plan(cfg.quality_requirement)),
    ];
    for (name, plan) in plans {
        match plan.and_then(|p| scenario.evaluate(&p)) {
            Ok(sim) => t.row(vec![
                name.into(),
                format!("{:.2}", sim.p95()),
                format!("{:.2}", sim.throughput_rps),
                format!("{:.1}", sim.quality),
            ]),
            Err(e) => t.row(vec![name.into(), "-".into(), "-".into(), format!("({e})")]),
        };
    }
    print!("{}", t.render());
    Ok(())
}

/// Parse a routing policy from CLI flags, sized to the artifact set's
/// tier count: `--policy threshold|length|margin`, `--h 80,70` (a
/// single value is replicated across all tier boundaries), plus
/// `--cutoff/--entry` for length and `--margin` for margin.
fn policy_from_args(args: &Args, n_tiers: usize) -> Result<PolicySpec> {
    let kind = PolicyKind::parse(&args.str_or("policy", "threshold"))?;
    let raw = args.str_or("h", "80");
    let mut thresholds: Vec<f64> = raw
        .split(',')
        .map(|s| s.trim().parse::<f64>().with_context(|| format!("--h entry '{s}'")))
        .collect::<Result<_>>()?;
    if thresholds.len() == 1 && n_tiers > 2 {
        thresholds = vec![thresholds[0]; n_tiers - 1];
    }
    match kind {
        PolicyKind::Threshold => PolicySpec::threshold(thresholds),
        PolicyKind::Length => PolicySpec::length(
            thresholds,
            args.f64_or("cutoff", 900.0)?,
            args.usize_or("entry", 1)?,
        ),
        PolicyKind::Margin => PolicySpec::margin(thresholds, args.f64_or("margin", 15.0)?),
    }
}

/// Serve the real tiny-tier cascade over TCP (requires artifacts).
/// `--plan plan.json` (a `cascadia schedule` capture) configures
/// routing entirely from the scheduler's artifact; otherwise the
/// policy comes from `--policy`/`--h` flags sized to the manifest's
/// tier count.
fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    let addr = args.str_or("addr", "127.0.0.1:8741");
    let max_new = args.usize_or("max-new", 8)?;
    let dir = std::env::var("CASCADIA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    let manifest = cascadia::runtime::Manifest::load(&dir)
        .context("artifacts missing — run `make artifacts` first")?;
    let n_tiers = manifest.tiers.len();
    let fe = match args.get("plan") {
        Some(path) => {
            let plan = CascadePlan::load(path)?;
            if plan.tiers.len() != n_tiers {
                bail!(
                    "plan has {} tiers but the artifact set has {n_tiers}",
                    plan.tiers.len()
                );
            }
            cascadia::coordinator::net::TcpFrontend::from_plan(&plan, max_new)?
        }
        None => cascadia::coordinator::net::TcpFrontend::new(
            policy_from_args(args, n_tiers)?,
            n_tiers,
            max_new,
        )?,
    };
    let judger = cascadia::runtime::TaskJudger::new(manifest.task.clone(), max_new.min(8));
    let factory = cascadia::runtime::pjrt_factory(dir);
    println!(
        "serving {n_tiers} tiers on {addr} (policy {}); protocol: one JSON per line",
        fe.policy_label()
    );
    fe.serve(&addr, &factory, &judger, Arc::new(AtomicBool::new(false)))
}

/// The calibrated serving benchmark: batch-lockstep vs the
/// continuous-batching engine on a bursty phase-shift trace; writes
/// `BENCH_serving.json` (the perf trajectory artifact CI tracks).
fn cmd_bench(args: &Args) -> Result<()> {
    use cascadia::engine::{run_serving_bench, BenchConfig};

    let mut cfg = if args.flag("smoke") { BenchConfig::smoke() } else { BenchConfig::full() };
    if args.flag("prefix-heavy") {
        cfg = cfg.prefix_heavy();
    }
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    eprintln!(
        "serving bench ({} mode): {} requests, time x{:.0}, {} tokens/step",
        if args.flag("smoke") { "smoke" } else { "full" },
        cfg.calm_requests + cfg.burst_requests,
        cfg.time_scale,
        cfg.token_scale,
    );
    let report = run_serving_bench(&cfg)?;

    let mut t = Table::new(
        &format!(
            "lockstep vs continuous engine (calm {:.2} rps → burst {:.2} rps, SCV {:.0})",
            report.calm_rate, report.burst_rate, report.burstiness
        ),
        &["mode", "p50(s)", "p95(s)", "p99(s)", "throughput", "makespan(s)"],
    );
    for m in [&report.lockstep, &report.continuous] {
        t.row(vec![
            m.label.clone(),
            format!("{:.2}", m.latency.p50),
            format!("{:.2}", m.latency.p95),
            format!("{:.2}", m.latency.p99),
            format!("{:.3} rps", m.throughput_rps),
            format!("{:.1}", m.makespan_s),
        ]);
    }
    print!("{}", t.render());
    for (i, e) in report.continuous.engine.iter().enumerate() {
        println!(
            "tier {i}: pages peak/pool {}/{} | preemptions {} | iterations {} | queue peak {} wait {:.2}s",
            e.peak_pages,
            e.pool_pages,
            e.preemptions,
            e.iterations,
            report.continuous.queue[i].peak_depth,
            report.continuous.queue[i].mean_wait_s,
        );
    }
    println!(
        "p95 speedup: {:.2}x | throughput gain: {:.2}x",
        report.p95_speedup, report.throughput_gain
    );
    println!(
        "prefix sharing ({} reqs, {}-token prefix): peak pages {} -> {} | prefilled tokens {} -> {} | hits {} | CoW {} | win {}",
        report.prefix.requests,
        report.prefix.shared_prefix_tokens,
        report.prefix.baseline_peak_pages,
        report.prefix.shared_peak_pages,
        report.prefix.baseline_prefill_tokens,
        report.prefix.shared_prefill_tokens,
        report.prefix.prefix_hit_tokens,
        report.prefix.cow_copies,
        report.prefix.win,
    );
    println!(
        "chunked prefill ({} reqs, {}-token longs, chunk {}): p95 TTFT {:.2}s -> {:.2}s ({:.2}x) | win {}",
        report.chunked.requests,
        report.chunked.long_prompt_tokens,
        report.chunked.prefill_chunk,
        report.chunked.whole_p95_ttft_s,
        report.chunked.chunked_p95_ttft_s,
        report.chunked.ttft_speedup,
        report.chunked.win,
    );
    println!(
        "swap preemption ({} reqs, {}-token prompts, {}-page pool): p95 {:.2}s -> {:.2}s ({:.2}x) | \
         prefilled tokens {} -> {} | preemptions {} | swaps {}/{} ({} B) | win {}",
        report.swap.requests,
        report.swap.prompt_tokens,
        report.swap.pool_pages,
        report.swap.recompute_p95_s,
        report.swap.swap_p95_s,
        report.swap.p95_speedup,
        report.swap.recompute_prefill_tokens,
        report.swap.swap_prefill_tokens,
        report.swap.preemptions,
        report.swap.swap_outs,
        report.swap.swap_ins,
        report.swap.swap_bytes,
        report.swap.win,
    );
    println!(
        "disaggregation ({} reqs, {}-token prompts, {} decode steps): \
         p95 TTFT unified {:.2}s -> split {:.2}s ({:.2}x) | \
         migrations {} ({} pages) | win {}",
        report.disagg.requests,
        report.disagg.prompt_tokens,
        report.disagg.decode_steps,
        report.disagg.unified_p95_ttft_s,
        report.disagg.disagg_p95_ttft_s,
        report.disagg.ttft_p95_speedup,
        report.disagg.migrations,
        report.disagg.migrate_pages,
        report.disagg.win,
    );
    println!(
        "tracing overhead ({} reqs): p95 off {:.2}s -> on {:.2}s ({:+.1}%) | \
         events {} | dropped {} | win {}",
        report.tracing.requests,
        report.tracing.p95_off_s,
        report.tracing.p95_on_s,
        report.tracing.overhead_frac * 100.0,
        report.tracing.events_recorded,
        report.tracing.dropped_events,
        report.tracing.win,
    );
    println!(
        "profile fold ({} reqs, {} matched): {} events in {:.3}s ({:.2}% of the {:.2}s run) | \
         p95 attribution err {:.4}s ({:.2}%) | win {}",
        report.profile.requests,
        report.profile.matched,
        report.profile.events_folded,
        report.profile.fold_wall_s,
        report.profile.fold_frac * 100.0,
        report.profile.run_wall_s,
        report.profile.p95_err_s,
        report.profile.p95_err_frac * 100.0,
        report.profile.win,
    );

    let out = args.str_or("out", "BENCH_serving.json");
    std::fs::write(&out, format!("{}\n", report.to_json()))
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {out}");
    if !report.occupancy_ok {
        bail!("KV page occupancy exceeded the pool budget");
    }
    if !report.win {
        bail!(
            "continuous engine did not beat the lockstep baseline \
             (p95 speedup {:.2}, throughput gain {:.2})",
            report.p95_speedup,
            report.throughput_gain
        );
    }
    if !report.prefix.win {
        bail!(
            "prefix sharing regressed: peak pages {} vs {} baseline, \
             prefilled tokens {} vs {} baseline",
            report.prefix.shared_peak_pages,
            report.prefix.baseline_peak_pages,
            report.prefix.shared_prefill_tokens,
            report.prefix.baseline_prefill_tokens
        );
    }
    if !report.chunked.win {
        bail!(
            "chunked prefill did not improve long-prompt-mix p95 TTFT \
             ({:.3}s chunked vs {:.3}s whole)",
            report.chunked.chunked_p95_ttft_s,
            report.chunked.whole_p95_ttft_s
        );
    }
    if !report.swap.win {
        bail!(
            "swap-to-host did not beat recompute-only preemption \
             (p95 {:.3}s vs {:.3}s, prefilled {} vs {})",
            report.swap.swap_p95_s,
            report.swap.recompute_p95_s,
            report.swap.swap_prefill_tokens,
            report.swap.recompute_prefill_tokens
        );
    }
    if !report.disagg.win {
        bail!(
            "the prefill/decode split did not beat unified serving \
             (p95 TTFT {:.3}s split vs {:.3}s unified, {} migrations)",
            report.disagg.disagg_p95_ttft_s,
            report.disagg.unified_p95_ttft_s,
            report.disagg.migrations
        );
    }
    if !report.tracing.win {
        bail!(
            "request-lifecycle tracing exceeded its overhead budget \
             (p95 {:.3}s on vs {:.3}s off, {} events, {} dropped)",
            report.tracing.p95_on_s,
            report.tracing.p95_off_s,
            report.tracing.events_recorded,
            report.tracing.dropped_events
        );
    }
    if !report.profile.win {
        bail!(
            "profile aggregation broke its budget: fold {:.4}s of a {:.4}s run \
             ({:.2}%), p95 attribution err {:.4}s ({:.2}%), {} of {} matched",
            report.profile.fold_wall_s,
            report.profile.run_wall_s,
            report.profile.fold_frac * 100.0,
            report.profile.p95_err_s,
            report.profile.p95_err_frac * 100.0,
            report.profile.matched,
            report.profile.requests
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "schedule" => cmd_schedule(&load_config(&args)?),
        "sweep" => cmd_sweep(&load_config(&args)?),
        "simulate" => cmd_simulate(&load_config(&args)?),
        "baselines" => cmd_baselines(&load_config(&args)?),
        "trace" => cmd_trace(&args),
        "replay" => cmd_replay(&args),
        "profile" => cmd_profile(&args),
        "top" => cmd_top(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "cascadia <schedule|sweep|simulate|baselines|trace|replay|profile|top|bench|serve> \\\n\
         \x20   [--config cfg.json] [--cascade deepseek|llama] [--gpus N] \\\n\
         \x20   [--trace 1..3] [--rate R] [--quality Q] [--n N] [--seed S] \\\n\
         \x20   [--policy threshold|length|margin]\n\n\
         Schedule-to-serve flow:\n\
         \x20   cascadia schedule --config cfg.json > plan.json\n\
         \x20   cascadia serve --plan plan.json\n\
         serve flags (without --plan): --h 80,70 --policy threshold \\\n\
         \x20   [--cutoff 900 --entry 1] [--margin 15] [--addr host:port]\n\n\
         Online adaptation (drift replay, §4.4):\n\
         \x20   cascadia replay --config examples/configs/drift_replay.json \\\n\
         \x20       [--trace-out replay_chrome.json] [--metrics-out replay.prom] \\\n\
         \x20       [--trace-frozen-out frozen_chrome.json]\n\n\
         Observability (request-lifecycle tracing + latency attribution):\n\
         \x20   cascadia trace --export chrome [--replicas N] [--out trace_chrome.json]\n\
         \x20   cascadia trace --diff    # paged DES vs live engine timeline diff\n\
         \x20   cascadia profile [--replay cfg.json] [--slo SECS] \\\n\
         \x20       [--out profile.json] [--metrics-out replay.prom]\n\
         \x20   cascadia top [--addr host:port] [--interval S] [--once] \\\n\
         \x20       [--profile-file profile.json] [--metrics-file replay.prom]\n\n\
         Serving benchmark (continuous engine vs lockstep baseline, plus\n\
         prefix-sharing, chunked-prefill, and swap-preemption sections):\n\
         \x20   cascadia bench [--smoke] [--prefix-heavy] [--seed S] [--out BENCH_serving.json]\n\n\
         Paper figures: cargo run --release --bin fig7_slo (etc.) — see DESIGN.md."
    );
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use cascadia::obs::{
        diff_timelines, Event, EventKind, Phase, ProfileAggregator, ProfileConfig,
    };
    use cascadia::util::json::Json;

    use super::*;

    /// The acceptance contract: the DES run and its live-engine twin
    /// produce *identical* per-request phase attribution — compared
    /// structurally (timestamp-free RLE signatures), since wall times
    /// differ by construction.
    #[test]
    fn des_and_live_attribution_identical_on_diff_harness() {
        let cfg = ExperimentConfig { n_requests: 32, ..ExperimentConfig::default() };
        let (des, live) = diff_harness_traces(&cfg).unwrap();
        let fold = |events: &[Event]| -> BTreeMap<u64, Vec<(Phase, u32)>> {
            let agg = ProfileAggregator::fold(ProfileConfig::default(), events);
            agg.waterfalls().iter().map(|w| (w.req, w.signature.clone())).collect()
        };
        let l = fold(&des);
        let r = fold(&live);
        assert_eq!(l.len(), 32, "every DES request folds to a waterfall");
        assert_eq!(l, r, "DES and live phase attribution diverge");
    }

    #[test]
    fn forced_divergence_fails_with_first_divergence() {
        let mk = |tok: u64| {
            let mut evs = Vec::new();
            let mut e = Event::at(0.0, 0, 0, EventKind::PrefillChunk);
            e.a = tok;
            e.c = 1;
            evs.push(e);
            evs.push(Event::at(0.1, 0, 0, EventKind::DecodeIter));
            let mut f = Event::at(0.2, 0, 0, EventKind::Finished);
            f.fa = 0.1;
            f.fb = 0.2;
            evs.push(f);
            for (i, e) in evs.iter_mut().enumerate() {
                e.seq = i as u64;
            }
            evs
        };
        let same = diff_timelines(&mk(4), &mk(4));
        assert!(trace_diff_verdict(&same).is_ok(), "identical timelines must pass");
        let report = diff_timelines(&mk(4), &mk(8));
        let err = trace_diff_verdict(&report).unwrap_err().to_string();
        assert!(err.contains("first divergence"), "{err}");
        assert!(err.contains("diverge"), "{err}");
    }

    #[test]
    fn top_frame_renders_health_table_and_alerts() {
        let profile = Json::parse(
            r#"{"requests":2,"open_requests":0,"events":10,"dropped_events":0,
                "hot_swaps":1,"trace_span_s":4.5,
                "e2e":{"p50_s":1.0,"p95_s":2.0,"mean_s":1.2},
                "tiers":[{"tier":0,"completed":2,"escalated_out":1,"queue_depth":3,
                          "queue_slope_per_s":0.25,"busy_frac":0.5,"window_p95_s":2.0,
                          "attainment_short":0.9,"attainment_long":0.95,
                          "burn_short":2.0,"burn_long":1.0}],
                "alerts":[{"kind":"slo_burn_rate","tier":0,"severity":"critical",
                           "evidence":"burn 2.0"}]}"#,
        )
        .unwrap();
        let metrics = "cascadia_requests_completed_total{tier=\"0\"} 2\n\
                       cascadia_e2e_latency_seconds_bucket{le=\"1\"} 2\n\
                       cascadia_trace_ring_occupancy{shard=\"0\"} 0.1\n";
        let frame = render_top_frame(Some(&profile), metrics);
        assert!(frame.contains("tier health"), "{frame}");
        assert!(frame.contains("ALERT [critical] slo_burn_rate"), "{frame}");
        assert!(frame.contains("cascadia_requests_completed_total"), "{frame}");
        assert!(frame.contains("cascadia_trace_ring_occupancy"), "{frame}");
        assert!(!frame.contains("latency_seconds_bucket"), "histograms stay out: {frame}");
    }
}
