//! Parallelism strategies: data parallelism (replication), tensor
//! parallelism and pipeline parallelism, in the paper's generalized
//! form where one model type's allocation is a *set of replicas, each
//! with its own (TP, PP)* — Table 2 shows mixed sets like
//! `s3: (TP=4, PP=3), (TP=8)`.
//!
//! [`enumerate_strategies`] generates every feasible strategy for a
//! model under a GPU budget, observing the constraints of §3.2:
//! Σ_replicas tp·pp ≤ f, per-GPU memory floors, TP confined to one
//! server (NVLink domain), and at most two distinct replica designs per
//! model type (the paper's case studies never use more).

use anyhow::Result;

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::util::json::Json;

/// Fraction of GPU memory reserved for activations/fragmentation.
pub const ACT_RESERVE: f64 = 0.10;
/// Minimum fraction of post-weight memory that must remain for KV cache
/// for a design to be considered servable.
pub const MIN_KV_FRAC: f64 = 0.05;

/// One replica design: `count` replicas, each tp-way sharded and
/// pp-stage pipelined.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ReplicaGroup {
    pub tp: usize,
    pub pp: usize,
    pub count: usize,
}

impl ReplicaGroup {
    pub fn gpus(&self) -> usize {
        self.tp * self.pp * self.count
    }
}

/// A parallelism strategy for one model type: a multiset of replica
/// designs (canonically sorted, largest design first).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Strategy {
    pub groups: Vec<ReplicaGroup>,
}

impl Strategy {
    pub fn new(mut groups: Vec<ReplicaGroup>) -> Strategy {
        groups.retain(|g| g.count > 0);
        groups.sort_by(|a, b| {
            (b.tp * b.pp, b.tp).cmp(&(a.tp * a.pp, a.tp))
        });
        Strategy { groups }
    }

    /// Single homogeneous design shorthand.
    pub fn uniform(tp: usize, pp: usize, count: usize) -> Strategy {
        Strategy::new(vec![ReplicaGroup { tp, pp, count }])
    }

    pub fn gpus(&self) -> usize {
        self.groups.iter().map(|g| g.gpus()).sum()
    }

    pub fn n_replicas(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Render in the paper's Table 2 notation, e.g.
    /// `(DP=2, TP=4)` or `(TP=4, PP=3), (TP=8)`.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        for g in &self.groups {
            let mut inner = Vec::new();
            if g.count > 1 {
                inner.push(format!("DP={}", g.count));
            }
            if g.tp > 1 {
                inner.push(format!("TP={}", g.tp));
            }
            if g.pp > 1 {
                inner.push(format!("PP={}", g.pp));
            }
            if inner.is_empty() {
                inner.push("DP=1".to_string());
            }
            parts.push(format!("({})", inner.join(", ")));
        }
        parts.join(", ")
    }

    /// Serialize for the plan artifact: the human-readable label plus
    /// the exact replica groups, so the plan round-trips losslessly.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label())),
            (
                "groups",
                Json::arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("tp", Json::num(g.tp as f64)),
                                ("pp", Json::num(g.pp as f64)),
                                ("count", Json::num(g.count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a strategy from its plan-JSON form.
    pub fn from_json(j: &Json) -> Result<Strategy> {
        let groups = j
            .req("groups")?
            .as_arr()?
            .iter()
            .map(|g| {
                Ok(ReplicaGroup {
                    tp: g.req("tp")?.as_usize()?,
                    pp: g.req("pp")?.as_usize()?,
                    count: g.req("count")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if groups.is_empty() || groups.iter().any(|g| g.tp == 0 || g.pp == 0 || g.count == 0) {
            anyhow::bail!("strategy must have at least one non-empty replica group");
        }
        Ok(Strategy::new(groups))
    }
}

/// Is a single replica design (tp, pp) feasible for this model on this
/// cluster? Checks the NVLink domain for TP, layer count for PP, and
/// the per-GPU memory floor (weights + activation reserve + a minimum
/// KV slice).
pub fn design_feasible(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    tp: usize,
    pp: usize,
) -> bool {
    if tp > cluster.gpus_per_server || !tp.is_power_of_two() {
        return false;
    }
    if pp == 0 || pp > model.n_layers {
        return false;
    }
    let usable = cluster.gpu.mem_bytes * (1.0 - ACT_RESERVE);
    let weight_per_gpu = model.weight_bytes() / (tp * pp) as f64;
    // Leave at least MIN_KV_FRAC of usable memory for KV cache.
    weight_per_gpu <= usable * (1.0 - MIN_KV_FRAC)
}

/// Feasible single-replica designs for `model` within `max_gpus`.
pub fn feasible_designs(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    max_gpus: usize,
) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let tps = [1usize, 2, 4, 8];
    for &tp in tps.iter().filter(|&&t| t <= cluster.gpus_per_server) {
        for pp in 1..=8usize {
            if tp * pp > max_gpus {
                continue;
            }
            if design_feasible(model, cluster, tp, pp) {
                out.push((tp, pp));
            }
        }
    }
    out
}

/// Enumerate all canonical strategies for `model` using at most
/// `budget` GPUs (and at least one replica), with at most two distinct
/// replica designs.
///
/// Strategies that leave GPUs idle are included only when nothing
/// larger fits (the inner optimizer's latency objective already prefers
/// to use the full allocation, and the MILP controls the budget).
pub fn enumerate_strategies(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    budget: usize,
) -> Vec<Strategy> {
    let designs = feasible_designs(model, cluster, budget);
    let mut out = Vec::new();
    // Single-design strategies.
    for &(tp, pp) in &designs {
        let cost = tp * pp;
        for count in 1..=(budget / cost) {
            out.push(Strategy::uniform(tp, pp, count));
        }
    }
    // Two-design mixes (distinct designs, both present).
    for i in 0..designs.len() {
        for j in (i + 1)..designs.len() {
            let (tp1, pp1) = designs[i];
            let (tp2, pp2) = designs[j];
            let (c1, c2) = (tp1 * pp1, tp2 * pp2);
            for n1 in 1..=(budget / c1) {
                let rem = budget - n1 * c1;
                for n2 in 1..=(rem / c2).min(budget) {
                    out.push(Strategy::new(vec![
                        ReplicaGroup { tp: tp1, pp: pp1, count: n1 },
                        ReplicaGroup { tp: tp2, pp: pp2, count: n2 },
                    ]));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{deepseek_cascade, llama_cascade};

    fn cluster() -> ClusterSpec {
        ClusterSpec::paper_testbed()
    }

    #[test]
    fn canonical_ordering_and_label() {
        let s = Strategy::new(vec![
            ReplicaGroup { tp: 8, pp: 1, count: 1 },
            ReplicaGroup { tp: 4, pp: 3, count: 1 },
        ]);
        // TP=4,PP=3 (12 GPUs) sorts before TP=8 (8 GPUs).
        assert_eq!(s.label(), "(TP=4, PP=3), (TP=8)");
        assert_eq!(s.gpus(), 20);
        assert_eq!(s.n_replicas(), 2);
    }

    #[test]
    fn dp_only_label() {
        assert_eq!(Strategy::uniform(1, 1, 4).label(), "(DP=4)");
        assert_eq!(Strategy::uniform(2, 1, 6).label(), "(DP=6, TP=2)");
    }

    #[test]
    fn strategy_json_roundtrip() {
        let s = Strategy::new(vec![
            ReplicaGroup { tp: 4, pp: 3, count: 1 },
            ReplicaGroup { tp: 8, pp: 1, count: 2 },
        ]);
        let text = s.to_json().to_string();
        let back = Strategy::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.label(), s.label());
        assert!(Strategy::from_json(&Json::parse(r#"{"groups": []}"#).unwrap()).is_err());
    }

    #[test]
    fn small_model_fits_everywhere() {
        let m = &deepseek_cascade()[0]; // 7B bf16, ~15 GB
        assert!(design_feasible(m, &cluster(), 1, 1));
    }

    #[test]
    fn large_model_needs_sharding() {
        let m = &deepseek_cascade()[1]; // 70B bf16, ~141 GB
        assert!(!design_feasible(m, &cluster(), 1, 1));
        assert!(!design_feasible(m, &cluster(), 2, 1));
        assert!(design_feasible(m, &cluster(), 4, 1));
        assert!(design_feasible(m, &cluster(), 2, 2));
    }

    #[test]
    fn tp_confined_to_server() {
        let m = &deepseek_cascade()[0];
        assert!(!design_feasible(m, &cluster(), 16, 1));
    }

    #[test]
    fn enumeration_respects_budget() {
        let m = &llama_cascade()[0];
        for budget in [1usize, 4, 8, 16] {
            let strategies = enumerate_strategies(m, &cluster(), budget);
            assert!(!strategies.is_empty());
            for s in &strategies {
                assert!(s.gpus() <= budget, "{} > {budget}", s.gpus());
                assert!(s.n_replicas() >= 1);
                assert!(s.groups.len() <= 2);
            }
        }
    }

    #[test]
    fn enumeration_excludes_infeasible_designs() {
        let m = &deepseek_cascade()[2]; // 671B INT4, ~336 GB
        let strategies = enumerate_strategies(m, &cluster(), 8);
        // Needs >= 5 GPUs of 72 GB usable each; tp*pp >= 5.
        for s in &strategies {
            for g in &s.groups {
                assert!(g.tp * g.pp >= 5, "infeasible design {:?}", g);
            }
        }
        assert!(!strategies.is_empty()); // TP=8 works
    }

    #[test]
    fn enumeration_has_no_duplicates() {
        let m = &llama_cascade()[0];
        let strategies = enumerate_strategies(m, &cluster(), 12);
        let mut seen = std::collections::HashSet::new();
        for s in &strategies {
            assert!(seen.insert(s.clone()), "duplicate {:?}", s);
        }
    }

    #[test]
    fn strategy_counts_stay_tractable() {
        let m = &deepseek_cascade()[0];
        let n = enumerate_strategies(m, &cluster(), 32).len();
        assert!(n > 50, "expected a rich space, got {n}");
        assert!(n < 20_000, "enumeration exploded: {n}");
    }
}
