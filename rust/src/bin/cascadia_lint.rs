//! `cascadia-lint` — run the in-repo concurrency & determinism
//! static-analysis pass over a source tree.
//!
//! ```text
//! cascadia-lint [ROOT]
//! ```
//!
//! `ROOT` defaults to `rust/src` when invoked from the repository root,
//! falling back to this crate's own `src/` directory otherwise. Output
//! is one `rel/path.rs:line: [rule] message` line per violation plus a
//! summary; exit code 0 when clean, 1 on violations, 2 on usage or io
//! errors. The same pass also runs under plain `cargo test` via the
//! tree-clean test in `cascadia::analysis` — this binary exists for CI
//! log visibility and ad-hoc local runs.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use cascadia::analysis::lint_tree;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() > 1 || args.iter().any(|a| a == "-h" || a == "--help") {
        eprintln!("usage: cascadia-lint [ROOT]");
        eprintln!("  ROOT defaults to rust/src, else this crate's src/ directory");
        return ExitCode::from(2);
    }
    let root: PathBuf = match args.first() {
        Some(r) => PathBuf::from(r),
        None => {
            let from_repo_root = Path::new("rust/src");
            if from_repo_root.is_dir() {
                from_repo_root.to_path_buf()
            } else {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
            }
        }
    };
    if !root.is_dir() {
        eprintln!("error: {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    match lint_tree(&root) {
        Ok(report) => {
            for line in report.render() {
                println!("{line}");
            }
            println!(
                "cascadia-lint: {} files, {} violation(s)",
                report.files,
                report.violations.len()
            );
            if report.violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::from(2)
        }
    }
}
