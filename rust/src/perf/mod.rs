//! Analytic performance model of one model replica under a parallelism
//! design — the cost kernel behind the latency simulator `S(w, f)`.
//!
//! Modeling assumptions (standard roofline + alpha-beta, documented so
//! the shape of every paper figure can be traced to a term):
//!
//! * **Prefill is compute-bound**: latency ≈ prompt_tokens ×
//!   flops/token ÷ (tp × eff_flops). Pipeline parallelism does not cut
//!   single-request prefill latency (stages run sequentially for one
//!   request) — it adds capacity via pipelining.
//! * **Decode is memory-bound**: every iteration each GPU re-reads its
//!   weight shard W/(tp·pp) plus the batch's KV slice; compute only
//!   matters at large batch.
//! * **TP all-reduce** per layer, 2 rings of (tp-1)/tp efficiency over
//!   the NVLink/IB link the group spans; this is why TP saturates and
//!   why TP across servers is poor (Figure 2's 3× spread).
//! * **PP handoff**: (pp-1) activation sends; cheap, but PP multiplies
//!   decode latency by the stage count while multiplying *capacity* by
//!   ~pp via microbatch pipelining.
//! * **Batching**: an iteration at batch B amortizes the weight reads
//!   over B requests — the continuous-batching win.

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::parallel::{ReplicaGroup, ACT_RESERVE};

/// Default KV page size (tokens) used by the paged execution engine
/// and the paged discrete-event simulator (vLLM's classic block size).
pub const DEFAULT_PAGE_TOKENS: usize = 16;

/// Default prefill chunk budget (tokens per iteration) of the
/// execution engine's Sarathi-style interleaved prefill. The analytic
/// scheduler models TTFT with the same budget
/// ([`ReplicaModel::ttft_chunked`]), so schedule-time estimates and
/// the runtime agree on prefill-cost semantics.
pub const DEFAULT_PREFILL_CHUNK: usize = 512;

/// Workload statistics for one model type, as the router sees them.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Request arrival rate, requests/s.
    pub rate: f64,
    /// Mean prompt length, tokens.
    pub avg_input: f64,
    /// Mean generation length, tokens.
    pub avg_output: f64,
}

impl Workload {
    pub fn scaled(&self, factor: f64) -> Workload {
        Workload { rate: self.rate * factor, ..*self }
    }
}

/// Precomputed per-replica cost model for (model, tp, pp) on a cluster.
#[derive(Debug, Clone)]
pub struct ReplicaModel {
    pub tp: usize,
    pub pp: usize,
    /// Seconds per prompt token of prefill (compute + TP comm).
    prefill_s_per_token: f64,
    /// Full weight-shard read time per iteration (batch-independent
    /// part for dense models; scaled by expert coverage for MoE).
    weight_read_s: f64,
    /// MoE geometry for the coverage curve ((0, 0) = dense).
    moe: (usize, usize),
    /// Fixed per-iteration comm floors (TP alpha + PP handoff).
    decode_fixed_s: f64,
    /// Incremental per-request-in-batch cost of a decode iteration:
    /// KV read + marginal compute + marginal comm.
    decode_per_req_s: f64,
    /// Max concurrent requests the KV memory supports.
    pub max_batch: usize,
    /// KV-cache bytes one token of context costs (whole replica group).
    kv_bytes_per_token: f64,
    /// GPU memory left for KV after weights + activation reserve
    /// (whole replica group, bytes).
    kv_budget_bytes: f64,
    /// PCIe alpha-beta terms for swap-to-host page moves.
    pcie_alpha: f64,
    pcie_beta_bw: f64,
    /// Alpha-beta terms of the link between two replicas of this
    /// design — the path a prefill→decode KV-page migration crosses.
    /// Derived from the interconnect a *pair* of replica groups spans:
    /// NVLink when both fit one server, the inter-server fabric
    /// otherwise.
    migrate_alpha: f64,
    migrate_beta_bw: f64,
    /// Pinned host memory backing swapped KV (whole replica group,
    /// bytes).
    host_swap_bytes: f64,
    /// Latency multiplier from pipeline depth (a request's token must
    /// traverse pp stages).
    pub pp_latency_factor: f64,
    /// Capacity multiplier from pipelining (pp microbatch groups in
    /// flight).
    pub pp_capacity_factor: f64,
}

impl ReplicaModel {
    /// Build the cost model. `avg_ctx` is the mean context length used
    /// to size the KV-limited max batch.
    pub fn new(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        tp: usize,
        pp: usize,
        avg_ctx: f64,
    ) -> ReplicaModel {
        let gpu = &cluster.gpu;
        let group = tp * pp;
        let link = cluster.link_for_group(group);

        // --- Prefill: compute term per token over tp GPUs ---
        let compute_s_per_token = model.flops_per_token()
            / (tp as f64 * gpu.eff_flops() * model.mfu_factor);
        // TP all-reduce per layer: 2 all-reduces of hidden activations
        // (bf16) per token, ring efficiency (tp-1)/tp.
        let ar_bytes_per_token = (model.hidden * 2) as f64;
        let tp_comm_s_per_token = if tp > 1 {
            model.n_layers as f64
                * 2.0
                * (2.0 * (tp as f64 - 1.0) / tp as f64)
                * ar_bytes_per_token
                / link.beta_bw
        } else {
            0.0
        };
        let prefill_s_per_token = compute_s_per_token + tp_comm_s_per_token;

        // --- Decode iteration ---
        // Fixed: each GPU reads its weight shard once per iteration;
        // stages are sequential for a given token (handled via
        // pp_latency_factor), so the fixed term is per stage.
        let weight_read_s = model.weight_bytes() / (tp * pp) as f64 / gpu.eff_hbm_bw();
        // Per-layer all-reduce alpha cost (latency floor) per iteration.
        let tp_alpha_s = if tp > 1 {
            model.n_layers as f64 * 2.0 * link.alpha * (tp as f64 - 1.0).log2().max(1.0)
        } else {
            0.0
        };
        // PP handoffs between consecutive stages.
        let pp_handoff_s = if pp > 1 {
            (pp - 1) as f64 * (link.alpha + (model.hidden * 2) as f64 / link.beta_bw)
        } else {
            0.0
        };
        let decode_fixed_s = tp_alpha_s + pp_handoff_s;

        // Incremental per request in the decode batch: its KV read
        // (spread across the group), one token of compute, one token of
        // all-reduce payload.
        let kv_read_s = model.kv_bytes_per_token() * avg_ctx / group as f64 / gpu.eff_hbm_bw();
        let marginal_compute_s = model.flops_per_token()
            / (group as f64 * gpu.eff_flops() * model.mfu_factor);
        let marginal_comm_s = if tp > 1 {
            model.n_layers as f64 * 2.0 * (2.0 * (tp as f64 - 1.0) / tp as f64)
                * ar_bytes_per_token
                / link.beta_bw
        } else {
            0.0
        };
        let decode_per_req_s = kv_read_s + marginal_compute_s + marginal_comm_s;

        // KV capacity across the replica's GPUs.
        let usable = gpu.mem_bytes * (1.0 - ACT_RESERVE) * group as f64;
        let kv_budget = (usable - model.weight_bytes()).max(0.0);
        let max_batch = if kv_budget <= 0.0 {
            0
        } else {
            ((kv_budget / (model.kv_bytes_per_token() * avg_ctx)) as usize).clamp(1, 512)
        };

        ReplicaModel {
            tp,
            pp,
            prefill_s_per_token,
            weight_read_s,
            moe: (model.n_experts, model.experts_per_token),
            decode_fixed_s,
            decode_per_req_s,
            max_batch,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            kv_budget_bytes: kv_budget,
            pcie_alpha: cluster.pcie.alpha,
            pcie_beta_bw: cluster.pcie.beta_bw,
            migrate_alpha: cluster.link_for_group(2 * group).alpha,
            migrate_beta_bw: cluster.link_for_group(2 * group).beta_bw,
            host_swap_bytes: cluster.host_swap_bytes_per_gpu * group as f64,
            pp_latency_factor: pp as f64,
            // Pipelining recovers most of the stage parallelism;
            // bubbles cost ~10%.
            pp_capacity_factor: if pp > 1 { 0.9 * pp as f64 } else { 1.0 },
        }
    }

    pub fn from_group(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        g: &ReplicaGroup,
        avg_ctx: f64,
    ) -> ReplicaModel {
        ReplicaModel::new(model, cluster, g.tp, g.pp, avg_ctx)
    }

    /// Latency to prefill a prompt of `tokens` tokens (seconds).
    pub fn prefill_latency(&self, tokens: f64) -> f64 {
        tokens * self.prefill_s_per_token
    }

    /// Fraction of the weights one iteration at batch `b` reads
    /// (mirrors `ModelSpec::weight_read_fraction`).
    fn weight_read_frac(&self, b: usize) -> f64 {
        let (e, k) = self.moe;
        if e == 0 || b == 0 {
            return 1.0;
        }
        let per_token = k as f64 / e as f64;
        0.08 + 0.92 * (1.0 - (1.0 - per_token).powi(b as i32))
    }

    /// Wall-clock of one decode iteration at batch size `b`: every
    /// in-flight request advances one token. A request's *perceived*
    /// inter-token latency includes the pipeline depth. For MoE models
    /// the weight-read term grows with batch (expert coverage), which
    /// is exactly why batching amortizes dense decode so much better.
    pub fn decode_iteration(&self, b: usize) -> f64 {
        (self.decode_fixed_s
            + self.weight_read_s * self.weight_read_frac(b)
            + self.decode_per_req_s * b as f64)
            * self.pp_latency_factor
    }

    /// Sustainable decode throughput (tokens/s) at batch `b`, with
    /// pipelining recovering stage concurrency.
    pub fn decode_throughput(&self, b: usize) -> f64 {
        if b == 0 {
            return 0.0;
        }
        let iter = self.decode_iteration(b) / self.pp_latency_factor;
        b as f64 / iter * (self.pp_capacity_factor / self.pp_latency_factor)
    }

    /// Mean service time of one whole request (prefill + all decode
    /// iterations) at steady batch `b` — the M/G/c service-time input.
    pub fn request_service_time(&self, w: &Workload, b: usize) -> f64 {
        self.prefill_latency(w.avg_input)
            + w.avg_output * self.decode_iteration(b) / (b as f64).max(1.0)
                * (b as f64 / self.pp_capacity_factor * self.pp_latency_factor).max(1.0)
                / (b as f64).max(1.0)
    }

    /// Max requests/s this replica sustains on workload `w`.
    ///
    /// Continuous-batching accounting (matches the DES): admissions
    /// charge their prefill into the iteration they join, stretching it
    /// for the *whole* batch, but all `b` in-flight requests still
    /// advance. With arrival rate λ the fraction of wall-clock spent in
    /// prefill is λ·pf, so per-request service rate solves
    ///   λ · a · (1 + λ·pf) = 1,   a = avg_output · iter(b) / b
    /// — a quadratic in λ.
    pub fn capacity(&self, w: &Workload) -> f64 {
        self.capacity_at_batch(w, self.max_batch)
    }

    /// [`ReplicaModel::capacity`] with a shared-prefix credit: when
    /// every request carries a `shared_prefix_tokens` common prompt
    /// prefix, the prefix's pages are resident once (the engine's
    /// prefix trie) and the KV budget holds more concurrent sequences
    /// — the steady batch, and with it the sustainable rate, grows.
    pub fn capacity_shared(&self, w: &Workload, shared_prefix_tokens: f64) -> f64 {
        if shared_prefix_tokens <= 0.0 {
            return self.capacity(w);
        }
        let avg_ctx = w.avg_input + w.avg_output;
        let b = self
            .max_batch_shared(avg_ctx, shared_prefix_tokens, DEFAULT_PAGE_TOKENS)
            .max(self.max_batch);
        self.capacity_at_batch(w, b)
    }

    fn capacity_at_batch(&self, w: &Workload, b: usize) -> f64 {
        if b == 0 {
            return 0.0;
        }
        let decode_tok_s = self.decode_throughput(b);
        let a = w.avg_output.max(1.0) / decode_tok_s.max(1e-12);
        let pf = self.prefill_latency(w.avg_input).max(1e-12);
        // pf·a·λ² + a·λ − 1 = 0.
        (-a + (a * a + 4.0 * pf * a).sqrt()) / (2.0 * pf * a)
    }

    /// Total KV pages of `page_tokens` tokens this replica's memory
    /// budget holds — the pool size of the paged execution engine
    /// ([`crate::engine::KvPool`]). 0 when the weights leave no KV
    /// room.
    pub fn kv_pages_total(&self, page_tokens: usize) -> usize {
        if self.kv_budget_bytes <= 0.0 || self.kv_bytes_per_token <= 0.0 {
            return 0;
        }
        (self.kv_budget_bytes / (self.kv_bytes_per_token * page_tokens.max(1) as f64)) as usize
    }

    /// Pages a single sequence of `ctx_tokens` context occupies.
    pub fn kv_pages_for(&self, ctx_tokens: f64, page_tokens: usize) -> usize {
        (ctx_tokens.max(1.0) / page_tokens.max(1) as f64).ceil() as usize
    }

    /// Page-granular feasibility: can one request of `ctx_tokens`
    /// context fit this replica's KV budget at all? Stricter than
    /// `max_batch > 0` — the request-count clamp rounds a fractional
    /// budget up to one slot even when a full-length request does not
    /// actually fit ([`crate::sched::inner`]'s feasibility screen uses
    /// this via the analytic simulator).
    pub fn fits_context(&self, ctx_tokens: f64) -> bool {
        self.kv_pages_for(ctx_tokens, DEFAULT_PAGE_TOKENS)
            <= self.kv_pages_total(DEFAULT_PAGE_TOKENS)
    }

    /// Max concurrent sequences the KV budget holds when every
    /// sequence shares a `shared_prefix_tokens` page-aligned prompt
    /// prefix (held once) and owns only its private remainder — the
    /// capacity credit prefix sharing buys the feasibility screen.
    /// Falls back to [`ReplicaModel::max_batch`] semantics at
    /// `shared_prefix_tokens = 0`.
    pub fn max_batch_shared(
        &self,
        avg_ctx: f64,
        shared_prefix_tokens: f64,
        page_tokens: usize,
    ) -> usize {
        let total = self.kv_pages_total(page_tokens);
        if total == 0 {
            return 0;
        }
        let shared = shared_prefix_tokens.clamp(0.0, avg_ctx);
        let shared_pages =
            ((shared / page_tokens.max(1) as f64).floor() as usize).min(total);
        let private_pages = self
            .kv_pages_for(avg_ctx, page_tokens)
            .saturating_sub(shared_pages)
            .max(1);
        ((total - shared_pages) / private_pages).clamp(1, 512)
    }

    /// Bytes one KV page of `page_tokens` tokens occupies on this
    /// replica (the unit swap-to-host moves over PCIe).
    pub fn kv_page_bytes(&self, page_tokens: usize) -> f64 {
        self.kv_bytes_per_token * page_tokens.max(1) as f64
    }

    /// Pages of `page_tokens` tokens the replica's pinned host swap
    /// budget holds — the bound of the engine's swap-to-host space
    /// (0 when the model has no KV or the host reserves nothing).
    pub fn swap_pages_total(&self, page_tokens: usize) -> usize {
        if self.host_swap_bytes <= 0.0 || self.kv_bytes_per_token <= 0.0 {
            return 0;
        }
        (self.host_swap_bytes / self.kv_page_bytes(page_tokens)) as usize
    }

    /// Seconds to move one KV page of `page_tokens` tokens across PCIe,
    /// one direction (alpha-beta). A swap-preempted victim pays two of
    /// these per page (out + in); the scheduler compares that against
    /// [`ReplicaModel::prefill_seconds_per_token`] x resident tokens.
    pub fn page_swap_seconds(&self, page_tokens: usize) -> f64 {
        self.pcie_alpha + self.kv_page_bytes(page_tokens) / self.pcie_beta_bw.max(1.0)
    }

    /// Seconds of prefill work per prompt token — the recompute-cost
    /// rate of the preemption policy's per-victim choice.
    pub fn prefill_seconds_per_token(&self) -> f64 {
        self.prefill_s_per_token
    }

    /// Seconds to move one KV page of `page_tokens` tokens to a peer
    /// replica over the modeled interconnect — the per-page cost of a
    /// prefill→decode migration. Same alpha-beta shape as
    /// [`ReplicaModel::page_swap_seconds`] but over the replica-pair
    /// link instead of PCIe (migration *is* swap with a peer-device
    /// destination), so the inner solver, the DES, and the serve-time
    /// transfer charge all price the handoff from this one formula.
    pub fn page_migrate_seconds(&self, page_tokens: usize) -> f64 {
        self.migrate_alpha + self.kv_page_bytes(page_tokens) / self.migrate_beta_bw.max(1.0)
    }

    /// One-way migration cost of a sequence holding `private_tokens`
    /// of unshared context: pages move once (no round trip — the
    /// decode side re-claims shared prefix pages from its own trie
    /// rather than pulling them over the link).
    pub fn migrate_seconds(&self, private_tokens: f64, page_tokens: usize) -> f64 {
        self.kv_pages_for(private_tokens, page_tokens) as f64
            * self.page_migrate_seconds(page_tokens)
    }

    /// Full swap cost of evicting-and-resuming a `ctx_tokens` victim:
    /// two PCIe moves (out + in) of every page its context occupies.
    /// THE per-victim swap cost — `sched::inner`'s plan-level choice,
    /// `sim::analytic`'s overhead term, and (through
    /// `PreemptionConfig::from_replica`'s rates) the runtime
    /// scheduler's eviction comparison all derive from this one
    /// formula, so they cannot drift apart.
    pub fn swap_round_trip_seconds(&self, ctx_tokens: f64, page_tokens: usize) -> f64 {
        2.0 * self.kv_pages_for(ctx_tokens, page_tokens) as f64
            * self.page_swap_seconds(page_tokens)
    }

    /// Time to first token under chunked prefill at steady batch `b`:
    /// the prompt's prefill is split into `ceil(prompt/chunk)` chunks,
    /// each sharing its iteration with the decode batch, so TTFT pays
    /// the full prefill plus one decode iteration per chunk. At
    /// `chunk >= prompt` this is exactly the unchunked
    /// `prefill + decode_iteration(b)` — the cost the pre-chunking
    /// model charged.
    pub fn ttft_chunked(&self, prompt_tokens: f64, chunk_tokens: f64, b: usize) -> f64 {
        let chunks = (prompt_tokens / chunk_tokens.max(1.0)).ceil().max(1.0);
        self.prefill_latency(prompt_tokens) + chunks * self.decode_iteration(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{deepseek_cascade, llama_cascade};

    fn cluster() -> ClusterSpec {
        ClusterSpec::paper_testbed()
    }

    fn w() -> Workload {
        Workload { rate: 1.0, avg_input: 512.0, avg_output: 256.0 }
    }

    #[test]
    fn tp_cuts_decode_latency() {
        let m = &llama_cascade()[0];
        let tp1 = ReplicaModel::new(m, &cluster(), 1, 1, 768.0);
        let tp4 = ReplicaModel::new(m, &cluster(), 4, 1, 768.0);
        assert!(tp4.decode_iteration(8) < tp1.decode_iteration(8));
    }

    #[test]
    fn tp_has_diminishing_returns() {
        let m = &llama_cascade()[0];
        let t = |tp: usize| ReplicaModel::new(m, &cluster(), tp, 1, 768.0).decode_iteration(8);
        let gain_12 = t(1) / t(2);
        let gain_48 = t(4) / t(8);
        assert!(gain_12 > gain_48, "{gain_12} vs {gain_48}");
    }

    #[test]
    fn pp_raises_latency_but_capacity_per_gpu_holds() {
        let m = &deepseek_cascade()[1];
        let pp1 = ReplicaModel::new(m, &cluster(), 4, 1, 768.0);
        let pp2 = ReplicaModel::new(m, &cluster(), 4, 2, 768.0);
        // Same-batch iteration latency is higher with pipeline depth.
        assert!(pp2.decode_iteration(8) > pp2.decode_fixed_s);
        assert!(
            pp2.decode_iteration(8) > pp1.decode_iteration(8) * 0.9,
            "pipeline should not make single-token latency better"
        );
        // But throughput per replica is comparable or better (bigger
        // memory pool, overlapped stages).
        assert!(pp2.decode_throughput(pp2.max_batch) > pp1.decode_throughput(pp1.max_batch) * 0.8);
    }

    #[test]
    fn prefill_latency_scales_with_tokens() {
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 2, 1, 768.0);
        let l1 = r.prefill_latency(256.0);
        let l2 = r.prefill_latency(1024.0);
        assert!((l2 / l1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn batching_amortizes_fixed_cost() {
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 2, 1, 768.0);
        let per_tok_b1 = r.decode_iteration(1) / 1.0;
        let per_tok_b16 = r.decode_iteration(16) / 16.0;
        assert!(per_tok_b16 < per_tok_b1 / 4.0, "batching should amortize");
    }

    #[test]
    fn capacity_positive_and_monotone_in_rate_independence() {
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 2, 1, 768.0);
        let c = r.capacity(&w());
        assert!(c > 0.1, "capacity {c} too low");
        // Longer outputs reduce capacity.
        let long = Workload { avg_output: 1024.0, ..w() };
        assert!(r.capacity(&long) < c);
    }

    #[test]
    fn big_model_slower_than_small() {
        let ds = deepseek_cascade();
        let small = ReplicaModel::new(&ds[0], &cluster(), 4, 1, 768.0);
        let big = ReplicaModel::new(&ds[2], &cluster(), 8, 1, 768.0);
        assert!(big.decode_iteration(8) > small.decode_iteration(8));
        assert!(big.prefill_latency(512.0) > small.prefill_latency(512.0));
    }

    #[test]
    fn max_batch_respects_memory() {
        let ds = deepseek_cascade();
        // 70B on exactly-fitting GPUs leaves little KV room.
        let tight = ReplicaModel::new(&ds[1], &cluster(), 4, 1, 4096.0);
        let roomy = ReplicaModel::new(&ds[1], &cluster(), 8, 1, 4096.0);
        assert!(roomy.max_batch > tight.max_batch);
    }

    #[test]
    fn paged_capacity_is_consistent_with_max_batch() {
        let m = &llama_cascade()[0];
        let avg_ctx = 768.0;
        let r = ReplicaModel::new(m, &cluster(), 1, 1, avg_ctx);
        let pages = r.kv_pages_total(DEFAULT_PAGE_TOKENS);
        let per_seq = r.kv_pages_for(avg_ctx, DEFAULT_PAGE_TOKENS);
        assert!(pages > 0 && per_seq > 0);
        // Requests-by-pages roughly reproduces the request-count bound
        // (up to the 512 clamp and page rounding).
        let by_pages = pages / per_seq;
        assert!(
            by_pages >= r.max_batch || r.max_batch == 512,
            "pages {pages} / per_seq {per_seq} = {by_pages} vs max_batch {}",
            r.max_batch
        );
        assert!(r.fits_context(avg_ctx));
        assert!(!r.fits_context(1e12), "absurd contexts cannot fit");
    }

    #[test]
    fn kv_pages_for_rounds_up() {
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 1, 1, 768.0);
        assert_eq!(r.kv_pages_for(1.0, 16), 1);
        assert_eq!(r.kv_pages_for(16.0, 16), 1);
        assert_eq!(r.kv_pages_for(17.0, 16), 2);
        assert_eq!(r.kv_pages_for(0.0, 16), 1);
    }

    #[test]
    fn shared_prefix_raises_capacity_and_feasibility() {
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 1, 1, 768.0);
        let base = r.max_batch_shared(768.0, 0.0, DEFAULT_PAGE_TOKENS);
        let shared = r.max_batch_shared(768.0, 512.0, DEFAULT_PAGE_TOKENS);
        assert!(shared > base, "sharing a 512-token prefix must add slots: {shared} vs {base}");
        // The capacity screen credits the extra concurrency.
        let wl = Workload { rate: 1.0, avg_input: 512.0, avg_output: 256.0 };
        assert!(r.capacity_shared(&wl, 448.0) >= r.capacity(&wl));
        assert_eq!(r.capacity_shared(&wl, 0.0), r.capacity(&wl));
    }

    #[test]
    fn swap_round_trip_beats_recompute_on_long_contexts() {
        // The regime the swap policy exists for: a deep-tier victim
        // with a long resident context is far cheaper to move over
        // PCIe than to re-prefill from token 0.
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 1, 1, 768.0);
        let ctx = 2048.0;
        let swap = r.swap_round_trip_seconds(ctx, DEFAULT_PAGE_TOKENS);
        let recompute = ctx * r.prefill_seconds_per_token();
        assert!(
            swap < recompute,
            "swap {swap}s should beat recompute {recompute}s at ctx {ctx}"
        );
        // And the host budget is deeper than the device pool: swap
        // space can park everything the pool ever held.
        assert!(r.swap_pages_total(DEFAULT_PAGE_TOKENS) > r.kv_pages_total(DEFAULT_PAGE_TOKENS));
        assert!(r.kv_page_bytes(DEFAULT_PAGE_TOKENS) > 0.0);
    }

    #[test]
    fn migration_prices_the_replica_pair_link() {
        let m = &llama_cascade()[0];
        // TP1: a prefill/decode replica pair fits one server, so
        // migration rides NVLink and beats the PCIe swap path.
        let r = ReplicaModel::new(m, &cluster(), 1, 1, 768.0);
        let mig = r.page_migrate_seconds(DEFAULT_PAGE_TOKENS);
        assert!(mig > 0.0);
        assert!(
            mig < r.page_swap_seconds(DEFAULT_PAGE_TOKENS),
            "intra-server migration should beat PCIe swap"
        );
        // TP8 on an 8-GPU server: the peer replica lives on another
        // server, so migration crosses the slower inter-server fabric.
        let wide = ReplicaModel::new(m, &cluster(), 8, 1, 768.0);
        assert!(
            wide.page_migrate_seconds(DEFAULT_PAGE_TOKENS)
                > r.page_migrate_seconds(DEFAULT_PAGE_TOKENS)
        );
        // One-way cost: pages move once, shared prefix never moves.
        let one_way = r.migrate_seconds(256.0, DEFAULT_PAGE_TOKENS);
        assert!((one_way - 16.0 * mig).abs() < 1e-12);
    }

    #[test]
    fn chunked_ttft_matches_unchunked_at_full_budget() {
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 2, 1, 768.0);
        let whole = r.prefill_latency(1024.0) + r.decode_iteration(8);
        let one_chunk = r.ttft_chunked(1024.0, 4096.0, 8);
        assert!((whole - one_chunk).abs() < 1e-12);
        // Finer chunks pay one extra interleaved iteration per chunk.
        let four = r.ttft_chunked(1024.0, 256.0, 8);
        assert!((four - (r.prefill_latency(1024.0) + 4.0 * r.decode_iteration(8))).abs() < 1e-12);
        assert!(four > one_chunk);
    }

    #[test]
    fn realistic_magnitudes() {
        // Sanity vs public H100 serving numbers: Llama3-8B TP1 decode
        // should be on the order of 5-20 ms/token at moderate batch.
        let m = &llama_cascade()[0];
        let r = ReplicaModel::new(m, &cluster(), 1, 1, 768.0);
        let it = r.decode_iteration(8);
        assert!(it > 0.002 && it < 0.050, "iteration {it}s out of range");
        // 70B TP8 prefill of 512 tokens should be order 0.05-0.5 s.
        let big = ReplicaModel::new(&llama_cascade()[1], &cluster(), 8, 1, 768.0);
        let pf = big.prefill_latency(512.0);
        assert!(pf > 0.01 && pf < 1.0, "prefill {pf}s out of range");
    }
}
