//! LLM architecture registry.
//!
//! The scheduler's cost model ([`crate::perf`]) only needs public
//! architecture constants — parameter counts, layer/head geometry,
//! weight precision — so the paper's model cascades are represented
//! faithfully even though the actual checkpoints cannot run here (the
//! e2e serving path uses the tiny tiers from `artifacts/` instead; see
//! DESIGN.md "Substitutions").

/// Weight precision of a served model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// bf16/fp16 — 2 bytes per parameter.
    Bf16,
    /// AWQ INT4 — 0.5 bytes per parameter (DeepSeek-671B in the paper).
    Int4,
}

impl Precision {
    pub fn bytes_per_param(self) -> f64 {
        match self {
            Precision::Bf16 => 2.0,
            Precision::Int4 => 0.5,
        }
    }
}

/// Architecture constants of one model type in a cascade.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Total parameters (all experts for MoE).
    pub n_params: f64,
    /// Parameters activated per token (== n_params for dense models).
    pub n_active_params: f64,
    pub n_layers: usize,
    pub hidden: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub precision: Precision,
    /// MoE: total routed experts per layer (0 = dense).
    pub n_experts: usize,
    /// MoE: experts activated per token (routed + shared).
    pub experts_per_token: usize,
    /// Achievable fraction of the hardware roofline for this model's
    /// serving kernels (MoE grouped-GEMM + all-to-all + INT4 dequant
    /// run far below dense-GEMM efficiency).
    pub mfu_factor: f64,
    /// Mean judger score (0-100) this model achieves on the evaluation
    /// workload — the calibration anchor for the synthetic judger
    /// (Figure 1 of the paper; see `judge/`).
    pub quality_mean: f64,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    /// Bytes of weights when fully materialized.
    pub fn weight_bytes(&self) -> f64 {
        self.n_params * self.precision.bytes_per_param()
    }

    /// KV-cache bytes per token (bf16 K and V across all layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.n_layers * self.n_kv_heads * self.head_dim()) as f64 * 2.0
    }

    /// FLOPs per token (forward): ~2 * active parameters; the attention
    /// score/value terms are absorbed by the 2*N rule at the sequence
    /// lengths used here.
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.n_active_params
    }

    /// Minimum GPUs needed just to hold the weights (plus a KV/activation
    /// reserve fraction) at a given per-GPU memory.
    pub fn min_gpus(&self, gpu_mem_bytes: f64, reserve_frac: f64) -> usize {
        let usable = gpu_mem_bytes * (1.0 - reserve_frac);
        (self.weight_bytes() / usable).ceil().max(1.0) as usize
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Expected fraction of weights a decode iteration at batch `b`
    /// must read. Dense models read everything once regardless of
    /// batch; an MoE batch collectively touches
    /// 1 - (1 - k/E)^b of the experts, which is why expert models lose
    /// most of the batching amortization that makes dense decode cheap.
    pub fn weight_read_fraction(&self, b: usize) -> f64 {
        if !self.is_moe() || b == 0 {
            return 1.0;
        }
        let per_token = self.experts_per_token as f64 / self.n_experts as f64;
        let coverage = 1.0 - (1.0 - per_token).powi(b as i32);
        // ~8% of parameters (attention, shared expert, router) are
        // dense and always read.
        0.08 + 0.92 * coverage
    }
}

/// DeepSeek cascade used in the paper's main evaluation:
/// DeepSeek-7B -> DeepSeek-70B (distill) -> DeepSeek-671B (AWQ INT4).
pub fn deepseek_cascade() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "DeepSeek-7B",
            n_params: 7.6e9,
            n_active_params: 7.6e9,
            n_layers: 28,
            hidden: 3584,
            n_heads: 28,
            n_kv_heads: 4,
            d_ff: 18944,
            vocab: 152064,
            precision: Precision::Bf16,
            n_experts: 0,
            experts_per_token: 0,
            mfu_factor: 1.0,
            quality_mean: 62.0,
        },
        ModelSpec {
            name: "DeepSeek-70B",
            n_params: 70.6e9,
            n_active_params: 70.6e9,
            n_layers: 80,
            hidden: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            d_ff: 28672,
            vocab: 128256,
            precision: Precision::Bf16,
            n_experts: 0,
            experts_per_token: 0,
            mfu_factor: 1.0,
            quality_mean: 83.0,
        },
        ModelSpec {
            // MoE: 671B total, ~37B activated per token; INT4 weights.
            name: "DeepSeek-671B-AWQ",
            n_params: 671.0e9,
            n_active_params: 37.0e9,
            n_layers: 61,
            hidden: 7168,
            n_heads: 128,
            // MLA compresses the KV cache ~16x vs vanilla MHA; model it
            // as an effective GQA-8 (within 2x of DeepSeek's published
            // per-token KV footprint).
            n_kv_heads: 8,
            d_ff: 18432,
            vocab: 129280,
            precision: Precision::Int4,
            // 256 routed experts, 8 routed + 1 shared active per token;
            // grouped-GEMM + all-to-all + INT4 dequant efficiency.
            n_experts: 256,
            experts_per_token: 9,
            mfu_factor: 0.35,
            quality_mean: 93.0,
        },
    ]
}

/// Llama cascade for the paper's Figure 9: Llama3-8B -> Llama3-70B.
pub fn llama_cascade() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "Llama3-8B",
            n_params: 8.0e9,
            n_active_params: 8.0e9,
            n_layers: 32,
            hidden: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            d_ff: 14336,
            vocab: 128256,
            precision: Precision::Bf16,
            n_experts: 0,
            experts_per_token: 0,
            mfu_factor: 1.0,
            quality_mean: 66.0,
        },
        ModelSpec {
            name: "Llama3-70B",
            n_params: 70.6e9,
            n_active_params: 70.6e9,
            n_layers: 80,
            hidden: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            d_ff: 28672,
            vocab: 128256,
            precision: Precision::Bf16,
            n_experts: 0,
            experts_per_token: 0,
            mfu_factor: 1.0,
            quality_mean: 86.0,
        },
    ]
}

/// Look up a cascade by name (used by configs and CLI).
pub fn cascade_by_name(name: &str) -> Option<Vec<ModelSpec>> {
    match name {
        "deepseek" => Some(deepseek_cascade()),
        "llama" => Some(llama_cascade()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cascade_is_ordered_by_capability() {
        for cascade in [deepseek_cascade(), llama_cascade()] {
            for w in cascade.windows(2) {
                assert!(w[0].quality_mean < w[1].quality_mean);
                assert!(w[0].n_params < w[1].n_params);
            }
        }
    }

    #[test]
    fn memory_floors_are_sane() {
        let c = deepseek_cascade();
        let h100 = 80e9;
        // 7B bf16 (~15 GB) fits on one H100.
        assert_eq!(c[0].min_gpus(h100, 0.3), 1);
        // 70B bf16 (~141 GB) needs at least 3 with a 30% reserve.
        assert!(c[1].min_gpus(h100, 0.3) >= 3);
        // 671B at INT4 (~336 GB) needs at least 6.
        assert!(c[2].min_gpus(h100, 0.3) >= 6);
        // And strictly more at bf16 than int4.
        let mut bf16 = c[2].clone();
        bf16.precision = Precision::Bf16;
        assert!(bf16.min_gpus(h100, 0.3) > c[2].min_gpus(h100, 0.3));
    }

    #[test]
    fn kv_bytes_match_hand_calc() {
        let m = &llama_cascade()[0]; // 8B: 32 layers, 8 kv heads, dim 128
        let expected = (2 * 32 * 8 * 128) as f64 * 2.0;
        assert_eq!(m.kv_bytes_per_token(), expected);
    }

    #[test]
    fn moe_flops_use_active_params() {
        let ds = deepseek_cascade();
        let big = &ds[2];
        assert!(big.flops_per_token() < 2.0 * big.n_params);
        assert_eq!(big.flops_per_token(), 2.0 * 37.0e9);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(cascade_by_name("deepseek").unwrap().len(), 3);
        assert_eq!(cascade_by_name("llama").unwrap().len(), 2);
        assert!(cascade_by_name("gpt").is_none());
    }
}
