//! Trace replay: measure the adaptation win.
//!
//! [`run_replay`] drives one drifting ([`PhasedTrace`]) trace through
//! the live serving engine twice — once with the plan **frozen** at
//! its startup schedule, once **adaptive** with the full monitor →
//! re-schedule → hot-swap loop — and reports per-phase SLO attainment
//! and judged quality for both, plus the adaptation counters. The
//! trace is replayed time-compressed (`time_scale`), with simulated
//! tier backends whose per-request service time is derived from the
//! same [`crate::perf::ReplicaModel`] cost model the scheduler
//! optimizes against, so a plan's provisioning means the same thing to
//! the scheduler and to the replayed server. Judging reuses the
//! offline [`Judger`] on the original request metadata, so routing
//! decisions match what the plan was optimized for.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterSpec;
use crate::coordinator::monitor::MonitorConfig;
use crate::coordinator::server::{
    CascadeServer, ResponseJudger, ServeControl, ServeTelemetry, ServerConfig, ServerStats,
    TierBackend, TierEngineStats, TierQueueStats, TraceEntry,
};
use crate::judge::Judger;
use crate::metrics::{AdaptCounters, LatencySummary};
use crate::models::{cascade_by_name, ModelSpec};
use crate::perf::ReplicaModel;
use crate::sched::outer::{optimize, select_plan, OuterOptions};
use crate::sched::plan::CascadePlan;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::sync::LockExt;
use crate::workload::{
    estimate_stats, generate, generate_phased, paper_trace, PhasedTrace, PhasedTraceSpec,
};

use super::controller::{AdaptConfig, AdaptController, Rescheduler, TraceObserver};

/// One workload phase of a replay (a regime of the drifting trace).
#[derive(Debug, Clone)]
pub struct PhaseConfig {
    /// Paper trace index 1..=3.
    pub trace_index: usize,
    /// Mean arrival rate, requests/s (uncompressed).
    pub rate: f64,
    pub n_requests: usize,
}

/// Full replay configuration (`examples/configs/drift_replay.json`).
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub cascade_name: String,
    pub n_gpus: usize,
    pub seed: u64,
    pub quality_requirement: f64,
    /// Threshold grid step for the (re-)scheduler sweep.
    pub threshold_step: f64,
    /// Wall-clock compression: arrivals and service times are divided
    /// by this factor, latencies multiplied back for reporting.
    pub time_scale: f64,
    /// SLO bound on uncompressed end-to-end latency, seconds.
    pub slo_seconds: f64,
    pub max_new_tokens: usize,
    /// Serve through the continuous-batching engine (paged KV pools
    /// sized from the plan's parallelism; the replay reports per-tier
    /// page occupancy and preemption counts). Set false to replay on
    /// the legacy whole-batch lockstep loop.
    pub continuous: bool,
    pub monitor: MonitorConfig,
    /// Arm the SLO burn-rate drift trigger on the adaptive run (see
    /// [`AdaptConfig::slo`]): completions breaching `slo_seconds` at a
    /// multi-window burn above `slo_burn_threshold` hot-swap even when
    /// the arrival mix looks stable to the workload monitor.
    pub slo_trigger: bool,
    /// Attainment target for the burn computation.
    pub slo_target: f64,
    /// Burn level both windows must exceed.
    pub slo_burn_threshold: f64,
    /// Burn windows, uncompressed seconds (scaled by `time_scale` for
    /// the compressed run, like every other duration here).
    pub slo_short_window_s: f64,
    pub slo_long_window_s: f64,
    pub phases: Vec<PhaseConfig>,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            cascade_name: "deepseek".into(),
            n_gpus: 32,
            seed: 7,
            quality_requirement: 80.0,
            threshold_step: 25.0,
            time_scale: 20.0,
            slo_seconds: 20.0,
            max_new_tokens: 8,
            continuous: true,
            monitor: MonitorConfig::default(),
            slo_trigger: false,
            slo_target: 0.9,
            slo_burn_threshold: 1.5,
            slo_short_window_s: 60.0,
            slo_long_window_s: 480.0,
            phases: vec![
                PhaseConfig { trace_index: 3, rate: 60.0, n_requests: 500 },
                PhaseConfig { trace_index: 1, rate: 10.0, n_requests: 600 },
            ],
        }
    }
}

impl ReplayConfig {
    pub fn load(path: impl AsRef<Path>) -> Result<ReplayConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading replay config {}", path.as_ref().display()))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<ReplayConfig> {
        let j = Json::parse(text).context("parsing replay config JSON")?;
        let mut c = ReplayConfig::default();
        if let Some(v) = j.get("cascade") {
            c.cascade_name = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("n_gpus") {
            c.n_gpus = v.as_usize()?;
        }
        if let Some(v) = j.get("seed") {
            c.seed = v.as_i64()? as u64;
        }
        if let Some(v) = j.get("quality_requirement") {
            c.quality_requirement = v.as_f64()?;
        }
        if let Some(v) = j.get("threshold_step") {
            c.threshold_step = v.as_f64()?;
        }
        if let Some(v) = j.get("time_scale") {
            c.time_scale = v.as_f64()?;
        }
        if let Some(v) = j.get("slo_seconds") {
            c.slo_seconds = v.as_f64()?;
        }
        if let Some(v) = j.get("max_new_tokens") {
            c.max_new_tokens = v.as_usize()?;
        }
        if let Some(v) = j.get("continuous") {
            c.continuous = v.as_bool()?;
        }
        if let Some(v) = j.get("slo_trigger") {
            c.slo_trigger = v.as_bool()?;
        }
        if let Some(v) = j.get("slo_target") {
            c.slo_target = v.as_f64()?;
        }
        if let Some(v) = j.get("slo_burn_threshold") {
            c.slo_burn_threshold = v.as_f64()?;
        }
        if let Some(v) = j.get("slo_short_window_s") {
            c.slo_short_window_s = v.as_f64()?;
        }
        if let Some(v) = j.get("slo_long_window_s") {
            c.slo_long_window_s = v.as_f64()?;
        }
        if let Some(m) = j.get("monitor") {
            if let Some(v) = m.get("window") {
                c.monitor.window = v.as_usize()?;
            }
            if let Some(v) = m.get("min_samples") {
                c.monitor.min_samples = v.as_usize()?;
            }
            if let Some(v) = m.get("shift_threshold") {
                c.monitor.shift_threshold = v.as_f64()?;
            }
        }
        if let Some(v) = j.get("phases") {
            c.phases = v
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(PhaseConfig {
                        trace_index: p.req("trace")?.as_usize()?,
                        rate: p.req("rate")?.as_f64()?,
                        n_requests: p.req("n_requests")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if cascade_by_name(&self.cascade_name).is_none() {
            bail!("unknown cascade '{}' (expected deepseek|llama)", self.cascade_name);
        }
        if self.phases.len() < 2 {
            bail!("a drift replay needs at least 2 phases, got {}", self.phases.len());
        }
        for (i, p) in self.phases.iter().enumerate() {
            if !(1..=3).contains(&p.trace_index) {
                bail!("phase {i}: trace index {} out of range 1..=3", p.trace_index);
            }
            if p.rate <= 0.0 || p.n_requests == 0 {
                bail!("phase {i}: rate and n_requests must be positive");
            }
        }
        if self.n_gpus == 0 || self.max_new_tokens == 0 {
            bail!("n_gpus and max_new_tokens must be positive");
        }
        if !(0.0..=100.0).contains(&self.quality_requirement) {
            bail!("quality requirement must be in 0..=100");
        }
        if self.threshold_step <= 0.0 || self.threshold_step > 50.0 {
            bail!("threshold_step must be in (0, 50]");
        }
        if self.time_scale < 1.0 {
            bail!("time_scale must be >= 1");
        }
        if self.slo_seconds <= 0.0 {
            bail!("slo_seconds must be positive");
        }
        if self.monitor.window == 0 || self.monitor.min_samples == 0 {
            bail!("monitor window/min_samples must be positive");
        }
        if self.slo_trigger {
            if !(0.0..1.0).contains(&self.slo_target) {
                bail!("slo_target must be in [0, 1)");
            }
            if self.slo_burn_threshold <= 0.0
                || self.slo_short_window_s <= 0.0
                || self.slo_long_window_s < self.slo_short_window_s
            {
                bail!("slo burn threshold/windows must be positive, long >= short");
            }
        }
        Ok(())
    }

    fn outer_options(&self) -> OuterOptions {
        let mut grid = Vec::new();
        let mut h = 0.0;
        while h <= 100.0 {
            grid.push(h);
            h += self.threshold_step;
        }
        OuterOptions { threshold_grid: grid, ..Default::default() }
    }

    fn phased_spec(&self) -> PhasedTraceSpec {
        PhasedTraceSpec {
            phases: self
                .phases
                .iter()
                .map(|p| (paper_trace(p.trace_index, p.rate), p.n_requests))
                .collect(),
        }
    }
}

/// Per-phase outcome of one replay run.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    pub label: String,
    pub requests: usize,
    /// Fraction of the phase's requests within `slo_seconds`
    /// (uncompressed end-to-end latency).
    pub slo_attainment: f64,
    pub mean_quality: f64,
    /// Uncompressed latency summary.
    pub latency: LatencySummary,
}

/// Outcome of one full replay run (frozen or adaptive).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub phases: Vec<PhaseReport>,
    pub overall_attainment: f64,
    pub mean_quality: f64,
    pub served: usize,
    /// Requests submitted but never completed. Always 0 when the run
    /// returned `Ok` — the server errors out rather than dropping — so
    /// this is the report's explicit statement of the zero-drop
    /// hot-swap contract, not a counter that can silently go nonzero.
    pub dropped: usize,
    pub counters: AdaptCounters,
    /// SLO burn-rate breach episodes observed by the adaptive run's
    /// controller (0 for the frozen run, and when the trigger is off).
    pub slo_breaches: usize,
    /// Per-tier queue telemetry (peak depth, mean admission wait —
    /// uncompressed seconds).
    pub queue: Vec<TierQueueStats>,
    /// Per-tier continuous-engine telemetry (page occupancy,
    /// preemptions; zeros when `continuous` is off).
    pub engine: Vec<TierEngineStats>,
}

/// The frozen-vs-adaptive comparison.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub initial_plan: String,
    /// Summary of the last plan the controller swapped in (None if no
    /// re-schedule fired).
    pub final_plan: Option<String>,
    pub slo_seconds: f64,
    pub frozen: RunReport,
    pub adaptive: RunReport,
}

impl ReplayReport {
    /// Did adapting beat serving the startup plan unchanged?
    pub fn adaptation_win(&self) -> bool {
        self.adaptive.overall_attainment > self.frozen.overall_attainment
    }
}

/// Simulated tier backend: per-request service time from the shared
/// speed table (seconds per request under the *current* plan's
/// parallelism, compressed by `time_scale`). Output encodes the
/// serving tier so the replay judger can score against the right
/// model.
struct SimBackend {
    tier: usize,
    speeds: Arc<Mutex<Vec<f64>>>,
    time_scale: f64,
}

impl TierBackend for SimBackend {
    fn generate(&mut self, _prompt: &[i32], _max_new: usize) -> Result<Vec<i32>> {
        let secs = self.speeds.plock()[self.tier] / self.time_scale;
        std::thread::sleep(Duration::from_secs_f64(secs.clamp(1e-5, 5.0)));
        Ok(vec![self.tier as i32])
    }
}

/// Scores a replayed response with the offline judger: the prompt's
/// first token carries the trace index of the original request, the
/// output's first token the serving tier.
struct ReplayJudger {
    requests: Vec<crate::workload::Request>,
    models: Vec<ModelSpec>,
    judger: Judger,
}

impl ResponseJudger for ReplayJudger {
    fn score(&self, prompt: &[i32], output: &[i32]) -> f64 {
        let id = prompt.first().copied().unwrap_or(0).max(0) as usize;
        let tier =
            (output.first().copied().unwrap_or(0).max(0) as usize).min(self.models.len() - 1);
        match self.requests.get(id) {
            Some(req) => self.judger.score(&self.models[tier], req, tier),
            None => 0.0,
        }
    }
}

/// Per-tier mean service seconds (uncompressed) implied by a plan's
/// parallelism under the scheduler's own cost model: one worker thread
/// stands for one replica running at its continuous-batching capacity.
/// Undeployed tiers keep a slow nominal backend (the plan routes no
/// steady-state traffic there).
fn tier_speeds(plan: &CascadePlan, cascade: &[ModelSpec], cluster: &ClusterSpec) -> Vec<f64> {
    plan.tiers
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let Some(strategy) = &t.strategy else {
                return 5.0;
            };
            let Some(group) = strategy.groups.first() else {
                return 5.0;
            };
            let avg_ctx = (t.workload.avg_input + t.workload.avg_output).max(64.0);
            let rm = ReplicaModel::from_group(&cascade[i], cluster, group, avg_ctx);
            let capacity = rm.capacity(&t.workload).max(1e-3);
            (1.0 / capacity).clamp(1e-4, 30.0)
        })
        .collect()
}

/// Aggregate one run's server stats into the per-phase report.
fn score_run(
    stats: &ServerStats,
    phased: &PhasedTrace,
    cfg: &ReplayConfig,
    counters: AdaptCounters,
) -> RunReport {
    let n_phases = phased.n_phases();
    let mut lat: Vec<Vec<f64>> = vec![Vec::new(); n_phases];
    let mut quality: Vec<Vec<f64>> = vec![Vec::new(); n_phases];
    for c in &stats.completions {
        let p = phased.phase_of(c.id);
        lat[p].push(c.e2e_latency.as_secs_f64() * cfg.time_scale);
        quality[p].push(c.score);
    }
    let phases: Vec<PhaseReport> = (0..n_phases)
        .map(|p| {
            let pc = &cfg.phases[p];
            PhaseReport {
                label: format!("phase{} (trace{}@{:.0}rps)", p + 1, pc.trace_index, pc.rate),
                requests: phased.phase_range(p).len(),
                slo_attainment: stats::fraction_within(&lat[p], cfg.slo_seconds),
                mean_quality: stats::mean(&quality[p]),
                latency: LatencySummary::of(&lat[p]),
            }
        })
        .collect();
    let all_lat: Vec<f64> = lat.iter().flatten().copied().collect();
    let all_q: Vec<f64> = quality.iter().flatten().copied().collect();
    RunReport {
        phases,
        overall_attainment: stats::fraction_within(&all_lat, cfg.slo_seconds),
        mean_quality: stats::mean(&all_q),
        served: stats.completions.len(),
        dropped: phased.requests.len() - stats.completions.len(),
        counters,
        slo_breaches: 0,
        queue: stats
            .queue
            .iter()
            .map(|q| TierQueueStats { mean_wait_s: q.mean_wait_s * cfg.time_scale, ..*q })
            .collect(),
        engine: stats.engine.clone(),
    }
}

/// Run the frozen-vs-adaptive drift replay. See the module docs.
pub fn run_replay(cfg: &ReplayConfig) -> Result<ReplayReport> {
    run_replay_with_obs(cfg, None, None)
}

/// [`run_replay`], with request-lifecycle tracing attached per run:
/// `telemetry` covers the **adaptive** run, `frozen_telemetry` (when
/// given) the frozen control run — two separate recorders, so the
/// frozen-vs-adaptive timelines can be diffed with the `cascadia
/// trace --diff` tooling. Leave `frozen_telemetry` at `None` to keep
/// the control run tracing-off (the unperturbed-comparison default).
/// The caller keeps its `Arc` clones of the telemetry to export span
/// timelines (Chrome trace) and scrape the metrics registries after
/// the replay returns.
pub fn run_replay_with_obs(
    cfg: &ReplayConfig,
    telemetry: Option<Arc<ServeTelemetry>>,
    frozen_telemetry: Option<Arc<ServeTelemetry>>,
) -> Result<ReplayReport> {
    cfg.validate()?;
    let cascade = cascade_by_name(&cfg.cascade_name).expect("validated");
    let cluster = ClusterSpec::with_gpus(cfg.n_gpus);
    let judger = Judger::new(cfg.seed);
    let opts = cfg.outer_options();

    // The drifting trace and the phase-1 planning sample.
    let phased = generate_phased(&cfg.phased_spec(), cfg.seed.wrapping_add(1));
    let p1 = &cfg.phases[0];
    let plan_reqs = generate(
        &paper_trace(p1.trace_index, p1.rate),
        p1.n_requests.max(200),
        cfg.seed.wrapping_add(2),
    );
    let sweep = optimize(&cascade, &cluster, &judger, &plan_reqs, cfg.n_gpus, &opts)
        .context("scheduling the initial (phase-1) plan")?;
    let plan = select_plan(&sweep, cfg.quality_requirement).with_context(|| {
        format!("no initial plan meets quality {}", cfg.quality_requirement)
    })?;
    let baseline = estimate_stats(&plan_reqs);

    // Live trace: compressed arrivals; the prompt's first token tags
    // the original request, its length carries the prompt length (so
    // length-predictive policies behave). Each entry carries its own
    // decode budget — the trace's output-length mixture, capped at the
    // configured ceiling — instead of one global depth.
    let trace: Vec<TraceEntry> = phased
        .requests
        .iter()
        .map(|r| {
            let len = (r.input_tokens as usize).clamp(2, 4096);
            let mut prompt = vec![0i32; len];
            prompt[0] = r.id as i32;
            TraceEntry {
                at: r.arrival / cfg.time_scale,
                prompt,
                max_new: Some((r.output_tokens.max(1) as usize).min(cfg.max_new_tokens)),
            }
        })
        .collect();

    let speeds = Arc::new(Mutex::new(tier_speeds(&plan, &cascade, &cluster)));
    let speeds_f = Arc::clone(&speeds);
    let time_scale = cfg.time_scale;
    let factory = move |tier: usize| -> Result<Box<dyn TierBackend>> {
        Ok(Box::new(SimBackend { tier, speeds: Arc::clone(&speeds_f), time_scale }))
    };
    let live_judger = ReplayJudger {
        requests: phased.requests.clone(),
        models: cascade.clone(),
        judger: judger.clone(),
    };
    let mut server = if cfg.continuous {
        CascadeServer::new(ServerConfig::from_plan_with_engine(
            &plan,
            &cascade,
            &cluster,
            cfg.max_new_tokens,
        )?)?
    } else {
        CascadeServer::from_plan(&plan, cfg.max_new_tokens)?
    };

    // --- Frozen run: the startup plan serves the whole drift. ---
    server.set_telemetry(frozen_telemetry);
    let stats_frozen = server
        .serve_entries(&trace, &factory, &live_judger)
        .context("frozen replay run")?;
    let frozen = score_run(&stats_frozen, &phased, cfg, AdaptCounters::default());

    // The adaptive run records into its own recorder (or none), so the
    // two timelines stay separately diffable.
    server.set_telemetry(telemetry);

    // --- Adaptive run: monitor → re-schedule → hot-swap live. (The
    // frozen run cannot have touched `speeds` — it has no controller
    // and therefore no on_swap hook.) ---
    let control = ServeControl::for_plan(&plan);
    let rescheduler = Rescheduler {
        cascade: cascade.clone(),
        cluster: cluster.clone(),
        judger: judger.clone(),
        opts: opts.clone(),
        n_gpus: cfg.n_gpus,
        quality_requirement: cfg.quality_requirement,
    };
    // The SLO trigger runs on the compressed clock: the bound and the
    // burn windows shrink by `time_scale`, matching the compressed
    // latencies the completion tap observes.
    let slo = cfg.slo_trigger.then(|| crate::obs::alert::SloBurnConfig {
        slo_s: cfg.slo_seconds / cfg.time_scale,
        target: cfg.slo_target,
        short_window_s: cfg.slo_short_window_s / cfg.time_scale,
        long_window_s: cfg.slo_long_window_s / cfg.time_scale,
        burn_threshold: cfg.slo_burn_threshold,
        min_samples: 20,
        clear_ratio: 0.5,
    });
    let adapt_cfg = AdaptConfig {
        monitor: cfg.monitor.clone(),
        max_new_tokens: cfg.max_new_tokens,
        continuous_engine: cfg.continuous,
        slo,
        ..Default::default()
    };
    let speeds_swap = Arc::clone(&speeds);
    let cascade_swap = cascade.clone();
    let cluster_swap = cluster.clone();
    let controller = Arc::new(
        AdaptController::new(adapt_cfg, rescheduler, baseline, Arc::clone(&control))
            .with_on_swap(move |new_plan| {
                *speeds_swap.plock() =
                    tier_speeds(new_plan, &cascade_swap, &cluster_swap);
            }),
    );
    let observer = TraceObserver::new(Arc::clone(&controller), phased.requests.clone());
    let stats_adaptive = server
        .serve_adaptive_entries(&trace, &factory, &live_judger, &control, Some(&observer))
        .context("adaptive replay run")?;
    // Let any still-running background re-schedule settle so counters
    // and the final-plan summary are complete.
    controller.wait_idle(Duration::from_secs(60));
    let mut counters = controller.counters();
    counters.hot_swaps = control.hot_swaps();
    let mut adaptive = score_run(&stats_adaptive, &phased, cfg, counters);
    adaptive.slo_breaches = controller.slo_breaches();

    Ok(ReplayReport {
        initial_plan: plan.summary(),
        final_plan: controller.last_plan().map(|p| p.summary()),
        slo_seconds: cfg.slo_seconds,
        frozen,
        adaptive,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_validate_and_parse() {
        ReplayConfig::default().validate().unwrap();
        let c = ReplayConfig::from_json_text(
            r#"{
                "cascade": "deepseek",
                "time_scale": 40,
                "monitor": {"window": 80, "min_samples": 50, "shift_threshold": 0.25},
                "phases": [
                    {"trace": 3, "rate": 30, "n_requests": 200},
                    {"trace": 1, "rate": 6, "n_requests": 200}
                ]
            }"#,
        )
        .unwrap();
        assert_eq!(c.phases.len(), 2);
        assert_eq!(c.monitor.window, 80);
        assert_eq!(c.time_scale, 40.0);
        assert_eq!(c.phases[1].trace_index, 1);
    }

    #[test]
    fn config_rejects_bad_values() {
        assert!(ReplayConfig::from_json_text(r#"{"cascade": "gpt"}"#).is_err());
        assert!(ReplayConfig::from_json_text(r#"{"time_scale": 0.5}"#).is_err());
        assert!(ReplayConfig::from_json_text(
            r#"{"phases": [{"trace": 1, "rate": 4, "n_requests": 100}]}"#
        )
        .is_err());
        assert!(ReplayConfig::from_json_text(
            r#"{"phases": [
                {"trace": 9, "rate": 4, "n_requests": 100},
                {"trace": 1, "rate": 4, "n_requests": 100}
            ]}"#
        )
        .is_err());
    }

    #[test]
    fn tier_speeds_are_positive_and_finite() {
        let cascade = crate::models::deepseek_cascade();
        let cluster = ClusterSpec::with_gpus(32);
        let judger = Judger::new(1);
        let reqs = generate(&paper_trace(2, 8.0), 300, 2);
        let opts = OuterOptions {
            threshold_grid: vec![0.0, 50.0, 90.0],
            ..Default::default()
        };
        let sweep = optimize(&cascade, &cluster, &judger, &reqs, 32, &opts).unwrap();
        let plan = select_plan(&sweep, 75.0).unwrap();
        let speeds = tier_speeds(&plan, &cascade, &cluster);
        assert_eq!(speeds.len(), cascade.len());
        for s in &speeds {
            assert!(*s > 0.0 && s.is_finite());
        }
    }
}
