//! The adaptation controller: owns the monitor → re-schedule →
//! hot-swap loop for one running server.
//!
//! The controller is fed from the server's admission tap
//! ([`AdmissionObserver`]); every observed request goes into the
//! sliding-window [`Monitor`]. When the monitor flags a workload
//! shift, the controller resolves it:
//!
//! * **cache hit** — a plan was already scheduled for this quantized
//!   regime ([`PlanCache`]): hot-swap it immediately, O(1);
//! * **cache miss** — run the full bi-level scheduler
//!   ([`crate::sched::outer::reschedule`]) on the monitor's recent
//!   window, by default in a detached background thread so the serve
//!   path never blocks on a MILP solve, then cache + hot-swap the
//!   result.
//!
//! Either way the swap goes through [`ServeControl::apply_plan`] and
//! the monitor is rebased onto the new regime. A failed re-schedule
//! (e.g. the quality bar is unreachable on the new mix) aborts the
//! trigger: the current plan keeps serving and detection re-arms on
//! fresh samples.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::ClusterSpec;
use crate::coordinator::monitor::{Monitor, MonitorConfig};
use crate::coordinator::server::{AdmissionObserver, ServeControl};
use crate::judge::Judger;
use crate::metrics::AdaptCounters;
use crate::models::ModelSpec;
use crate::obs::alert::{SloBurnConfig, SloBurnMonitor};
use crate::obs::Clock;
use crate::sched::outer::{self, OuterOptions};
use crate::sched::plan::CascadePlan;
use crate::util::sync::LockExt;
use crate::workload::{Request, TraceStats};

use super::cache::{CacheConfig, PlanCache, RegimeKey};

/// Everything a background re-schedule needs to re-run the bi-level
/// scheduler: the scenario inputs of `sched::outer::optimize` plus the
/// quality requirement plans must keep meeting.
#[derive(Debug, Clone)]
pub struct Rescheduler {
    pub cascade: Vec<ModelSpec>,
    pub cluster: ClusterSpec,
    pub judger: Judger,
    pub opts: OuterOptions,
    pub n_gpus: usize,
    pub quality_requirement: f64,
}

impl Rescheduler {
    /// Run the §4.4 re-scheduling path on a monitor window.
    pub fn plan_for(&self, window: &[Request]) -> Result<CascadePlan> {
        outer::reschedule(
            &self.cascade,
            &self.cluster,
            &self.judger,
            window,
            self.n_gpus,
            &self.opts,
            self.quality_requirement,
        )
    }
}

/// Controller knobs.
#[derive(Debug, Clone)]
pub struct AdaptConfig {
    pub monitor: MonitorConfig,
    pub cache: CacheConfig,
    /// `max_new_tokens` for configurations derived from swapped plans.
    pub max_new_tokens: usize,
    /// Run re-schedules synchronously on the observing thread instead
    /// of a background thread — deterministic, for tests.
    pub synchronous: bool,
    /// Build swapped configurations for the continuous-batching engine
    /// (`ServerConfig::from_plan_with_engine`): each hot-swap rescales
    /// the per-tier KV pools to the new plan's parallelism. Should
    /// match the exec mode the adapted server was launched with; a
    /// mismatch is benign but suboptimal — the serve loop never
    /// changes mode mid-run, so a lockstep config swapped onto a
    /// continuous server leaves the KV pools at their last sizing
    /// instead of retuning them to the new plan.
    pub continuous_engine: bool,
    /// SLO burn-rate drift trigger (`None` = workload monitor only).
    /// When set, completion latencies feed a [`SloBurnMonitor`]; a
    /// multi-window burn breach triggers the same re-schedule /
    /// plan-cache path as a detected workload shift — a deployment can
    /// miss its latency SLO while the arrival *mix* looks unchanged
    /// (queue buildup, swap storms, escalation cascades), and the
    /// workload monitor alone never sees that.
    pub slo: Option<SloBurnConfig>,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            monitor: MonitorConfig::default(),
            cache: CacheConfig::default(),
            max_new_tokens: 8,
            synchronous: false,
            continuous_engine: false,
            slo: None,
        }
    }
}

/// The monitor → re-schedule → hot-swap controller. Shared as an
/// `Arc` between the admission tap and its background re-schedule
/// threads.
pub struct AdaptController {
    config: AdaptConfig,
    rescheduler: Rescheduler,
    control: Arc<ServeControl>,
    monitor: Mutex<Monitor>,
    cache: Mutex<PlanCache>,
    counters: Mutex<AdaptCounters>,
    last_plan: Mutex<Option<CascadePlan>>,
    /// Cooldowns for regimes whose re-schedule failed (e.g. the
    /// quality bar is unreachable on that mix): the next few triggers
    /// in the same bucket are skipped before retrying. Without this,
    /// a persistent shift re-runs the full bi-level sweep every
    /// `min_samples` requests — one guaranteed-to-fail MILP sweep per
    /// second at moderate rates; with a permanent blacklist, a bucket
    /// that first failed on a mixed phase-boundary window could never
    /// schedule again even once the regime settles.
    failed_regimes: Mutex<std::collections::HashMap<RegimeKey, u32>>,
    /// The SLO-drift trigger (None when `config.slo` is None).
    slo: Option<Mutex<SloBurnMonitor>>,
    /// Burn-rate breaches observed (each is one alert episode; a
    /// breach while a trigger is already pending or the window is
    /// underfilled still counts here even though no new re-schedule
    /// starts).
    slo_breaches: AtomicUsize,
    /// Background re-schedules currently running.
    in_flight: AtomicUsize,
    /// Hook run after every successful swap (e.g. the replay harness
    /// retunes its simulated backends to the new parallelism).
    on_swap: Option<Box<dyn Fn(&CascadePlan) + Send + Sync>>,
}

impl AdaptController {
    /// `baseline` is the stats the initially-served plan was scheduled
    /// for; `control` must belong to the server this controller adapts.
    pub fn new(
        config: AdaptConfig,
        rescheduler: Rescheduler,
        baseline: TraceStats,
        control: Arc<ServeControl>,
    ) -> AdaptController {
        let monitor = Monitor::new(config.monitor.clone(), baseline);
        let cache = PlanCache::new(config.cache.clone());
        let slo = config.slo.clone().map(|c| Mutex::new(SloBurnMonitor::new(c)));
        AdaptController {
            config,
            rescheduler,
            control,
            monitor: Mutex::new(monitor),
            cache: Mutex::new(cache),
            counters: Mutex::new(AdaptCounters::default()),
            last_plan: Mutex::new(None),
            failed_regimes: Mutex::new(std::collections::HashMap::new()),
            slo,
            slo_breaches: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            on_swap: None,
        }
    }

    /// Install a post-swap hook (builder-style, before `Arc`-wrapping).
    pub fn with_on_swap(
        mut self,
        hook: impl Fn(&CascadePlan) + Send + Sync + 'static,
    ) -> AdaptController {
        self.on_swap = Some(Box::new(hook));
        self
    }

    /// Feed one admitted request into the monitor; kicks off the
    /// re-schedule pipeline when a shift is detected.
    pub fn observe(self: &Arc<Self>, req: Request) {
        let drift = self.monitor.plock().observe(req);
        let Some(stats) = drift else { return };
        self.counters.plock().drifts_detected += 1;
        self.resolve(stats);
    }

    /// Feed one completion latency into the SLO burn-rate trigger.
    /// A multi-window burn breach resolves through the same pipeline
    /// as a workload shift — and through the same pending-trigger
    /// suppression ([`Monitor::trigger_external`]), so the two trigger
    /// sources cannot storm each other: while either one's re-schedule
    /// is in flight, both stay quiet. The burn monitor itself is
    /// edge-triggered (one breach per episode, re-arming only on
    /// recovery), so a suppressed breach does not re-fire on the next
    /// completion either.
    pub fn observe_completion(self: &Arc<Self>, now_s: f64, e2e_s: f64) {
        let Some(slo) = &self.slo else { return };
        let breach = slo.plock().observe(now_s, e2e_s);
        if breach.is_none() {
            return;
        }
        self.slo_breaches.fetch_add(1, Ordering::SeqCst);
        let triggered = self.monitor.plock().trigger_external();
        let Some(stats) = triggered else { return };
        self.counters.plock().drifts_detected += 1;
        self.resolve(stats);
    }

    /// Shared post-detection pipeline: plan-cache hit, failed-regime
    /// cooldown, else a (possibly background) re-schedule.
    fn resolve(self: &Arc<Self>, stats: TraceStats) {
        // Gear cache first: a known regime swaps in without touching
        // the scheduler.
        let cached = self.cache.plock().get(&stats).cloned();
        if let Some(plan) = cached {
            self.apply(stats, plan, true);
            return;
        }

        // A regime that just failed to re-schedule will fail again —
        // skip its cooldown's worth of triggers (the current plan keeps
        // serving) before retrying with a fresh window.
        let key = RegimeKey::of(&stats, &self.config.cache);
        {
            let mut failed = self.failed_regimes.plock();
            if let Some(remaining) = failed.get_mut(&key) {
                *remaining -= 1;
                if *remaining == 0 {
                    failed.remove(&key);
                }
                drop(failed);
                self.monitor.plock().abort_reschedule();
                return;
            }
        }

        let window: Vec<Request> = self.monitor.plock().window_requests().to_vec();
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        if self.config.synchronous {
            self.run_reschedule(stats, window);
        } else {
            let me = Arc::clone(self);
            std::thread::spawn(move || me.run_reschedule(stats, window));
        }
    }

    fn run_reschedule(&self, stats: TraceStats, window: Vec<Request>) {
        match self.rescheduler.plan_for(&window) {
            Ok(plan) => {
                self.cache.plock().insert(&stats, plan.clone());
                self.apply(stats, plan, false);
            }
            Err(_) => {
                // Keep serving the current plan; put the regime on a
                // cooldown (skip the next few triggers in this bucket)
                // so the same unschedulable mix doesn't re-run the
                // sweep every min_samples requests.
                let mut failed = self.failed_regimes.plock();
                if failed.len() >= 64 {
                    failed.clear();
                }
                failed.insert(RegimeKey::of(&stats, &self.config.cache), 3);
                drop(failed);
                self.monitor.plock().abort_reschedule();
            }
        }
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }

    fn apply(&self, stats: TraceStats, plan: CascadePlan, from_cache: bool) {
        // The swapped configuration carries engine pool sizing when the
        // server runs continuous — the hot-swap rescales the per-tier
        // KV pools along with the policy and worker pools.
        let built = if self.config.continuous_engine {
            crate::coordinator::server::ServerConfig::from_plan_with_engine(
                &plan,
                &self.rescheduler.cascade,
                &self.rescheduler.cluster,
                self.config.max_new_tokens,
            )
        } else {
            crate::coordinator::server::ServerConfig::from_plan(
                &plan,
                self.config.max_new_tokens,
            )
        };
        match built.and_then(|cfg| self.control.apply_plan_config(&plan, cfg)) {
            Ok(()) => {
                let reschedules = {
                    let mut m = self.monitor.plock();
                    m.rebased(stats);
                    m.reschedules
                };
                {
                    let mut c = self.counters.plock();
                    c.reschedules = reschedules;
                    c.hot_swaps += 1;
                    if from_cache {
                        c.plan_cache_hits += 1;
                    }
                }
                *self.last_plan.plock() = Some(plan.clone());
                // Stale pre-swap latencies must not bias post-swap
                // burn; the breach latch is kept (one corrective
                // action per episode) until attainment recovers.
                if let Some(slo) = &self.slo {
                    slo.plock().reset_after_swap();
                }
                if let Some(hook) = &self.on_swap {
                    hook(&plan);
                }
            }
            Err(_) => self.monitor.plock().abort_reschedule(),
        }
    }

    /// Loop counters so far. `hot_swaps` counts plans the controller
    /// queued; the server-side count of swaps actually applied is
    /// `ServeControl::hot_swaps`.
    pub fn counters(&self) -> AdaptCounters {
        *self.counters.plock()
    }

    /// Burn-rate breach episodes observed by the SLO trigger.
    pub fn slo_breaches(&self) -> usize {
        self.slo_breaches.load(Ordering::SeqCst)
    }

    /// The most recently swapped-in plan, if any.
    pub fn last_plan(&self) -> Option<CascadePlan> {
        self.last_plan.plock().clone()
    }

    /// Block until no background re-schedule is running (or `timeout`
    /// elapses). Returns true when idle.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.in_flight.load(Ordering::SeqCst) > 0 {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }
}

/// Bridges the server's index-based admission tap to the controller
/// using the trace's request metadata: the live path only knows the
/// trace index, the monitor wants the `workload::Request`.
pub struct TraceObserver {
    controller: Arc<AdaptController>,
    requests: Vec<Request>,
    /// Stamps completion times for the SLO burn windows (wall seconds
    /// since observer construction — the same time base the observed
    /// e2e latencies are measured on).
    clock: Clock,
}

impl TraceObserver {
    pub fn new(controller: Arc<AdaptController>, requests: Vec<Request>) -> TraceObserver {
        TraceObserver { controller, requests, clock: Clock::wall() }
    }
}

impl AdmissionObserver for TraceObserver {
    fn on_admit(&self, req_index: usize) {
        if let Some(r) = self.requests.get(req_index) {
            self.controller.observe(*r);
        }
    }

    fn on_complete(&self, _tier: usize, e2e_s: f64) {
        self.controller.observe_completion(self.clock.now(), e2e_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::workload::{estimate_stats, generate, paper_trace};

    fn rescheduler() -> Rescheduler {
        Rescheduler {
            cascade: deepseek_cascade(),
            cluster: ClusterSpec::paper_testbed(),
            judger: Judger::new(5),
            opts: OuterOptions {
                threshold_grid: vec![0.0, 50.0, 90.0],
                ..Default::default()
            },
            n_gpus: 32,
            quality_requirement: 75.0,
        }
    }

    fn controller(quality: f64) -> (Arc<AdaptController>, Arc<ServeControl>) {
        let control = ServeControl::new(3);
        let baseline = estimate_stats(&generate(&paper_trace(3, 10.0), 400, 1));
        let mut r = rescheduler();
        r.quality_requirement = quality;
        let cfg = AdaptConfig { synchronous: true, ..Default::default() };
        let c = Arc::new(AdaptController::new(cfg, r, baseline, Arc::clone(&control)));
        (c, control)
    }

    #[test]
    fn drift_triggers_reschedule_and_swap() {
        let (c, control) = controller(75.0);
        // Shifted workload: hard trace at a different rate.
        for req in generate(&paper_trace(1, 7.0), 300, 2) {
            c.observe(req);
            if c.counters().reschedules > 0 {
                break;
            }
        }
        let counters = c.counters();
        assert!(counters.drifts_detected >= 1, "{counters}");
        assert_eq!(counters.reschedules, 1, "{counters}");
        assert_eq!(counters.plan_cache_hits, 0, "first regime visit cannot hit");
        assert!(c.last_plan().is_some());
        // The plan sits in the server's swap mailbox (the serve loop
        // would consume it); the control saw no applied swap yet.
        assert_eq!(control.hot_swaps(), 0);
    }

    #[test]
    fn repeat_regime_hits_the_cache() {
        let (c, _control) = controller(75.0);
        let hard = || generate(&paper_trace(1, 7.0), 400, 3);
        let easy = || generate(&paper_trace(3, 10.0), 400, 4);
        for req in hard() {
            c.observe(req);
            if c.counters().reschedules >= 1 {
                break;
            }
        }
        assert_eq!(c.counters().reschedules, 1);
        // Back to the baseline-like regime...
        for req in easy() {
            c.observe(req);
            if c.counters().reschedules >= 2 {
                break;
            }
        }
        assert_eq!(c.counters().reschedules, 2);
        // ...and back to the hard regime: this one is cached.
        for req in hard() {
            c.observe(req);
            if c.counters().reschedules >= 3 {
                break;
            }
        }
        let counters = c.counters();
        assert_eq!(counters.reschedules, 3, "{counters}");
        assert!(counters.plan_cache_hits >= 1, "repeat regime must hit the cache: {counters}");
    }

    #[test]
    fn unreachable_quality_aborts_and_keeps_serving() {
        // A quality bar no plan can meet: the re-schedule fails, the
        // trigger aborts, and the controller never swaps.
        let (c, control) = controller(100.1);
        for req in generate(&paper_trace(1, 7.0), 400, 5) {
            c.observe(req);
        }
        let counters = c.counters();
        assert!(counters.drifts_detected >= 1);
        assert_eq!(counters.reschedules, 0, "{counters}");
        assert_eq!(counters.hot_swaps, 0);
        assert!(c.last_plan().is_none());
        assert_eq!(control.hot_swaps(), 0);
    }

    #[test]
    fn slo_burn_breach_triggers_hot_swap_without_storming() {
        // The arrival MIX stays at the baseline (the workload monitor
        // sees no shift); only completion latencies breach the SLO.
        let control = ServeControl::new(3);
        let baseline_reqs = generate(&paper_trace(3, 10.0), 400, 1);
        let baseline = estimate_stats(&baseline_reqs);
        let cfg = AdaptConfig {
            synchronous: true,
            // A deliberately deaf workload monitor: only the SLO
            // trigger can fire in this test (sampling noise on a
            // 100-request window must not drift-trigger).
            monitor: MonitorConfig { shift_threshold: 10.0, ..Default::default() },
            slo: Some(crate::obs::alert::SloBurnConfig {
                slo_s: 1.0,
                target: 0.9,
                short_window_s: 30.0,
                long_window_s: 120.0,
                burn_threshold: 1.5,
                min_samples: 10,
                clear_ratio: 0.5,
            }),
            ..Default::default()
        };
        let c = Arc::new(AdaptController::new(cfg, rescheduler(), baseline, control));
        // Stable mix fills the monitor window; no workload drift fires.
        for req in generate(&paper_trace(3, 10.0), 100, 20) {
            c.observe(req);
        }
        assert_eq!(c.counters().hot_swaps, 0, "stable mix must not drift-trigger");
        // Load breaches the burn threshold: every completion misses the
        // 1s SLO on both windows. Exactly one corrective hot-swap.
        for i in 0..20 {
            c.observe_completion(10.0 + i as f64 * 0.5, 5.0);
        }
        let counters = c.counters();
        assert_eq!(c.slo_breaches(), 1, "burn breach is edge-triggered");
        assert_eq!(counters.hot_swaps, 1, "breach must hot-swap once: {counters}");
        assert_eq!(counters.drifts_detected, 1);
        // Continued breaches while latched: no re-fire storm.
        for i in 0..40 {
            c.observe_completion(25.0 + i as f64 * 0.5, 5.0);
        }
        assert_eq!(c.slo_breaches(), 1, "latched episode must not re-fire");
        assert_eq!(c.counters().hot_swaps, 1);
        // Recovery clears the latch; the monitor window refills.
        for req in generate(&paper_trace(3, 10.0), 100, 21) {
            c.observe(req);
        }
        for i in 0..40 {
            c.observe_completion(100.0 + i as f64 * 0.5, 0.2);
        }
        assert_eq!(c.slo_breaches(), 1, "recovery must not breach");
        // A fresh breach episode re-fires and swaps again.
        for i in 0..40 {
            c.observe_completion(300.0 + i as f64 * 0.5, 5.0);
        }
        let counters = c.counters();
        assert_eq!(c.slo_breaches(), 2, "re-armed trigger fires again");
        assert_eq!(counters.hot_swaps, 2, "{counters}");
    }

    #[test]
    fn on_swap_hook_sees_the_new_plan() {
        let control = ServeControl::new(3);
        let baseline = estimate_stats(&generate(&paper_trace(3, 10.0), 400, 1));
        let seen = Arc::new(AtomicUsize::new(0));
        let seen2 = Arc::clone(&seen);
        let cfg = AdaptConfig { synchronous: true, ..Default::default() };
        let c = Arc::new(
            AdaptController::new(cfg, rescheduler(), baseline, control).with_on_swap(
                move |plan| {
                    assert_eq!(plan.tiers.len(), 3);
                    seen2.fetch_add(1, Ordering::SeqCst);
                },
            ),
        );
        for req in generate(&paper_trace(1, 7.0), 300, 6) {
            c.observe(req);
            if seen.load(Ordering::SeqCst) > 0 {
                break;
            }
        }
        assert_eq!(seen.load(Ordering::SeqCst), 1);
    }
}
