//! Online adaptation (§4.4): the closed schedule→serve loop at runtime.
//!
//! The static pipeline froze the served
//! [`crate::sched::plan::CascadePlan`] at startup; this subsystem owns
//! what happens *after* startup:
//!
//! 1. every admitted request is fed into the coordinator's workload
//!    [`crate::coordinator::Monitor`] (through the server's
//!    [`crate::coordinator::server::AdmissionObserver`] tap);
//! 2. on a detected shift the [`controller::AdaptController`] first
//!    consults a CascadeServe-style precomputed-plan cache
//!    ([`cache::PlanCache`], keyed by quantized workload-stats
//!    buckets) so a regime seen before swaps in O(1); on a miss it
//!    re-runs the full bi-level scheduler
//!    ([`crate::sched::outer::reschedule`]) on the monitor's recent
//!    window in a background thread;
//! 3. the resulting plan is hot-swapped into the running
//!    [`crate::coordinator::CascadeServer`] via
//!    [`crate::coordinator::server::ServeControl`] — routing policy,
//!    admission bounds and worker pools change without dropping
//!    in-flight requests.
//!
//! [`replay`] is the measurement harness: it drives a drifting
//! ([`crate::workload::PhasedTrace`]) trace through the full
//! monitor→re-schedule→hot-swap loop and reports per-phase SLO
//! attainment/quality for the adaptive run against a frozen-plan run
//! (`cascadia replay --config examples/configs/drift_replay.json`).

pub mod cache;
pub mod controller;
pub mod replay;

pub use cache::{CacheConfig, PlanCache, RegimeKey};
pub use controller::{AdaptConfig, AdaptController, Rescheduler, TraceObserver};
pub use replay::{
    run_replay, run_replay_with_obs, PhaseConfig, ReplayConfig, ReplayReport, RunReport,
};
