//! CascadeServe-style precomputed-plan cache ("gears"): cascade plans
//! keyed by quantized workload-regime buckets, so a regime the system
//! has served before swaps back in O(1) with no scheduler run.
//!
//! The key quantizes [`TraceStats`] — log-scale buckets for the
//! arrival rate (regimes are ratio-, not difference-shaped) and linear
//! buckets for the length and complexity means. Capacity is bounded
//! with FIFO eviction: under regime churn old gears age out.

use std::collections::{HashMap, VecDeque};

use crate::sched::plan::CascadePlan;
use crate::workload::TraceStats;

/// Bucketing resolution and capacity of the plan cache.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Rate buckets are log-scale: one bucket spans a factor of
    /// `rate_factor` in requests/s.
    pub rate_factor: f64,
    /// Linear bucket width for the mean input/output lengths (tokens).
    pub len_bucket: f64,
    /// Linear bucket width for the mean complexity (in [0, 1]).
    pub complexity_bucket: f64,
    /// Max cached plans (FIFO eviction).
    pub capacity: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            rate_factor: 1.5,
            len_bucket: 200.0,
            complexity_bucket: 0.1,
            capacity: 32,
        }
    }
}

/// A quantized workload regime — the cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RegimeKey {
    rate: i32,
    input: i32,
    output: i32,
    complexity: i32,
}

impl RegimeKey {
    pub fn of(stats: &TraceStats, cfg: &CacheConfig) -> RegimeKey {
        let log_bucket = |x: f64, factor: f64| {
            if x <= 0.0 {
                -1000
            } else {
                (x.ln() / factor.ln()).floor() as i32
            }
        };
        let lin_bucket = |x: f64, width: f64| (x.max(0.0) / width.max(1e-9)).floor() as i32;
        RegimeKey {
            rate: log_bucket(stats.rate, cfg.rate_factor),
            input: lin_bucket(stats.avg_input, cfg.len_bucket),
            output: lin_bucket(stats.avg_output, cfg.len_bucket),
            complexity: lin_bucket(stats.complexity_mean, cfg.complexity_bucket),
        }
    }
}

/// The bounded regime→plan cache. (Hit accounting lives in the
/// controller's `AdaptCounters::plan_cache_hits` — a hit only counts
/// once the cached plan is actually applied.)
#[derive(Debug)]
pub struct PlanCache {
    config: CacheConfig,
    entries: HashMap<RegimeKey, CascadePlan>,
    order: VecDeque<RegimeKey>,
}

impl PlanCache {
    pub fn new(config: CacheConfig) -> PlanCache {
        PlanCache { config, entries: HashMap::new(), order: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The plan previously scheduled for this stats regime, if any.
    pub fn get(&self, stats: &TraceStats) -> Option<&CascadePlan> {
        self.entries.get(&RegimeKey::of(stats, &self.config))
    }

    /// Remember the plan scheduled for this regime (replaces any plan
    /// already cached for the same bucket; evicts FIFO at capacity).
    pub fn insert(&mut self, stats: &TraceStats, plan: CascadePlan) {
        let key = RegimeKey::of(stats, &self.config);
        if self.entries.insert(key, plan).is_none() {
            self.order.push_back(key);
            while self.entries.len() > self.config.capacity.max(1) {
                if let Some(old) = self.order.pop_front() {
                    self.entries.remove(&old);
                } else {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Strategy;
    use crate::perf::Workload;
    use crate::router::PolicySpec;
    use crate::sched::plan::TierPlan;

    fn plan(q: f64) -> CascadePlan {
        CascadePlan {
            policy: PolicySpec::threshold(vec![50.0]).unwrap(),
            tiers: vec![
                TierPlan {
                    model_name: "small".into(),
                    gpus: 4,
                    strategy: Some(Strategy::uniform(1, 1, 4)),
                    workload: Workload { rate: 4.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 1.0,
                    predicted_p95: 1.0,
                    disagg: None,
                    speculation: None,
                },
                TierPlan {
                    model_name: "large".into(),
                    gpus: 8,
                    strategy: Some(Strategy::uniform(4, 1, 2)),
                    workload: Workload { rate: 1.0, avg_input: 300.0, avg_output: 100.0 },
                    processing_ratio: 0.25,
                    predicted_p95: 2.0,
                    disagg: None,
                    speculation: None,
                },
            ],
            predicted_latency: 2.0,
            predicted_quality: q,
            preemption: Vec::new(),
        }
    }

    fn stats(rate: f64, input: f64, complexity: f64) -> TraceStats {
        TraceStats { rate, avg_input: input, avg_output: 200.0, complexity_mean: complexity }
    }

    #[test]
    fn nearby_stats_share_a_bucket_and_hit() {
        let mut c = PlanCache::new(CacheConfig::default());
        let s = stats(4.0, 300.0, 0.42);
        assert!(c.get(&s).is_none());
        c.insert(&s, plan(80.0));
        // Small jitter (same bucket) hits; a regime change misses.
        let jitter = stats(4.2, 310.0, 0.44);
        assert!(c.get(&jitter).is_some(), "same regime must hit");
        let surge = stats(12.0, 300.0, 0.42);
        assert!(c.get(&surge).is_none(), "3x rate is a different regime");
        let harder = stats(4.0, 300.0, 0.72);
        assert!(c.get(&harder).is_none(), "complexity shift is a different regime");
    }

    #[test]
    fn capacity_evicts_fifo() {
        let cfg = CacheConfig { capacity: 2, ..Default::default() };
        let mut c = PlanCache::new(cfg);
        let s1 = stats(1.0, 100.0, 0.1);
        let s2 = stats(10.0, 500.0, 0.5);
        let s3 = stats(40.0, 1500.0, 0.9);
        c.insert(&s1, plan(70.0));
        c.insert(&s2, plan(80.0));
        c.insert(&s3, plan(90.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&s1).is_none(), "oldest entry must be evicted");
        assert!(c.get(&s2).is_some());
        assert!(c.get(&s3).is_some());
    }

    #[test]
    fn reinsert_same_bucket_replaces_without_growth() {
        let mut c = PlanCache::new(CacheConfig::default());
        let s = stats(4.0, 300.0, 0.4);
        c.insert(&s, plan(70.0));
        c.insert(&s, plan(90.0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&s).unwrap().predicted_quality, 90.0);
    }

    #[test]
    fn zero_rate_is_a_valid_bucket() {
        let cfg = CacheConfig::default();
        let k = RegimeKey::of(&stats(0.0, 0.0, 0.0), &cfg);
        let k2 = RegimeKey::of(&stats(0.0, 0.0, 0.0), &cfg);
        assert_eq!(k, k2);
    }
}
