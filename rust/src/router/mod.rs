//! Policy-driven cascade routing (§3.3, Figure 5).
//!
//! Every request enters the cascade at the tier its [`RoutingPolicy`]
//! picks (the smallest tier unless the policy predicts difficulty from
//! request features); the judger scores each response and the policy
//! accepts it, escalates one tier, or skips ahead. The last tier
//! always accepts. Routing a concrete trace yields the per-tier
//! *processing ratios* `p_i`, the per-tier workloads `w_i` consumed by
//! the inner MILP, and the overall quality metric `Q(θ)` — i.e.
//! everything the outer optimization iterates on.
//!
//! [`route_with`] is the generic entry point; [`route`] is the legacy
//! fixed-threshold wrapper kept for the original call sites and its
//! panic-on-bad-arity contract.

pub mod policy;

pub use policy::{
    monotone_chains, Decision, LengthPolicy, MarginPolicy, PolicyKind, PolicySpec,
    RequestFeatures, RoutingPolicy, ThresholdPolicy, THRESHOLD_MAX,
};

use anyhow::{bail, Result};

use crate::judge::Judger;
use crate::models::ModelSpec;
use crate::perf::Workload;
use crate::workload::Request;

/// Legacy routing thresholds `h_1..h_{C-1}` (score in [0, 100]; a
/// request is accepted at tier i when its score >= h_i). Kept as the
/// raw, unvalidated form; [`ThresholdPolicy`] is the validated port.
#[derive(Debug, Clone, PartialEq)]
pub struct Thresholds(pub Vec<f64>);

impl Thresholds {
    pub fn uniform(c_minus_1: usize, h: f64) -> Thresholds {
        Thresholds(vec![h; c_minus_1])
    }
}

/// Result of routing one trace through the cascade.
#[derive(Debug, Clone)]
pub struct RoutingOutcome {
    /// Accepting tier index per request (aligned with the trace).
    pub accepting_tier: Vec<u8>,
    /// Tiers each request actually visited, in visit order (policies
    /// with entry prediction or skip decisions do not visit every tier
    /// up to the accepting one).
    pub visited_tiers: Vec<Vec<u8>>,
    /// Fraction of requests processed by each tier (p_i; p_0 == 1 for
    /// policies that always enter at the bottom).
    pub processing_ratios: Vec<f64>,
    /// Workload each tier sees (visits, not accepts).
    pub tier_workloads: Vec<Workload>,
    /// Mean judged score of the accepted responses — Q(θ).
    pub quality: f64,
    /// Judged score each request finally received.
    pub final_scores: Vec<f64>,
}

/// Route `requests` through `cascade` under `policy`.
///
/// `span_seconds` is the observation window used to turn visit counts
/// into rates; pass the trace's true span. Fails if the policy's
/// parameters don't fit the cascade or the policy emits an invalid
/// skip target.
pub fn route_with(
    cascade: &[ModelSpec],
    judger: &Judger,
    requests: &[Request],
    policy: &dyn RoutingPolicy,
    span_seconds: f64,
) -> Result<RoutingOutcome> {
    let c = cascade.len();
    if c == 0 {
        bail!("empty cascade");
    }
    policy.validate(c)?;
    if !(span_seconds > 0.0) {
        bail!("span_seconds must be positive, got {span_seconds}");
    }

    let mut accepting = vec![0u8; requests.len()];
    let mut final_scores = vec![0.0f64; requests.len()];
    let mut visited_tiers: Vec<Vec<u8>> = Vec::with_capacity(requests.len());
    let mut visits = vec![0usize; c];
    let mut in_tokens = vec![0f64; c];
    let mut out_tokens = vec![0f64; c];

    for (idx, req) in requests.iter().enumerate() {
        let features = RequestFeatures::of(req);
        let mut tier = policy.entry_tier(&features, c).min(c - 1);
        let mut visited: Vec<u8> = Vec::with_capacity(2);
        loop {
            visits[tier] += 1;
            in_tokens[tier] += req.input_tokens as f64;
            out_tokens[tier] += req.output_tokens as f64;
            visited.push(tier as u8);
            let score = judger.score(&cascade[tier], req, tier);
            let decision = if tier == c - 1 {
                Decision::Accept
            } else {
                policy.decide(tier, score, &features, c)
            };
            match decision {
                Decision::Accept => {
                    accepting[idx] = tier as u8;
                    final_scores[idx] = score;
                    break;
                }
                Decision::Escalate => tier += 1,
                Decision::SkipTo(t) => {
                    if t <= tier || t >= c {
                        bail!(
                            "policy skipped from tier {tier} to invalid tier {t} \
                             (must move strictly forward within {c} tiers)"
                        );
                    }
                    tier = t;
                }
            }
        }
        visited_tiers.push(visited);
    }

    let n = requests.len() as f64;
    let processing_ratios: Vec<f64> = visits.iter().map(|&v| v as f64 / n.max(1.0)).collect();
    let tier_workloads: Vec<Workload> = (0..c)
        .map(|t| Workload {
            rate: visits[t] as f64 / span_seconds,
            avg_input: if visits[t] > 0 { in_tokens[t] / visits[t] as f64 } else { 0.0 },
            avg_output: if visits[t] > 0 { out_tokens[t] / visits[t] as f64 } else { 0.0 },
        })
        .collect();
    let quality = if requests.is_empty() {
        0.0
    } else {
        final_scores.iter().sum::<f64>() / n
    };

    Ok(RoutingOutcome {
        accepting_tier: accepting,
        visited_tiers,
        processing_ratios,
        tier_workloads,
        quality,
        final_scores,
    })
}

/// Route `requests` through `cascade` with fixed `thresholds` — the
/// legacy entry point, equivalent to [`route_with`] under a
/// [`ThresholdPolicy`]. Panics on invalid thresholds (original
/// contract); new code should construct a policy and call
/// [`route_with`].
pub fn route(
    cascade: &[ModelSpec],
    judger: &Judger,
    requests: &[Request],
    thresholds: &Thresholds,
    span_seconds: f64,
) -> RoutingOutcome {
    let policy = ThresholdPolicy::new(thresholds.0.clone())
        .unwrap_or_else(|e| panic!("invalid thresholds: {e}"));
    route_with(cascade, judger, requests, &policy, span_seconds)
        .unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::workload::{generate, paper_trace};

    fn setup() -> (Vec<ModelSpec>, Judger, Vec<Request>, f64) {
        let cascade = deepseek_cascade();
        let judger = Judger::new(1);
        let reqs = generate(&paper_trace(2, 4.0), 1500, 3);
        let span = reqs.last().unwrap().arrival;
        (cascade, judger, reqs, span)
    }

    #[test]
    fn zero_thresholds_accept_everything_at_tier_one() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds::uniform(2, 0.0), span);
        assert!(out.accepting_tier.iter().all(|&t| t == 0));
        assert_eq!(out.processing_ratios, vec![1.0, 0.0, 0.0]);
        assert_eq!(out.tier_workloads[1].rate, 0.0);
    }

    #[test]
    fn max_thresholds_send_everything_to_the_top() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds::uniform(2, 101.0), span);
        assert!(out.accepting_tier.iter().all(|&t| t == 2));
        assert_eq!(out.processing_ratios, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn ratios_are_monotone_decreasing_along_cascade() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(
            &cascade,
            &judger,
            &reqs,
            &Thresholds(vec![70.0, 60.0]),
            span,
        );
        assert_eq!(out.processing_ratios[0], 1.0);
        assert!(out.processing_ratios[0] >= out.processing_ratios[1]);
        assert!(out.processing_ratios[1] >= out.processing_ratios[2]);
        assert!(out.processing_ratios[1] > 0.0);
    }

    #[test]
    fn higher_thresholds_escalate_more_and_raise_quality() {
        let (cascade, judger, reqs, span) = setup();
        let low = route(&cascade, &judger, &reqs, &Thresholds(vec![30.0, 30.0]), span);
        let high = route(&cascade, &judger, &reqs, &Thresholds(vec![85.0, 85.0]), span);
        assert!(high.processing_ratios[2] > low.processing_ratios[2]);
        assert!(high.quality > low.quality, "{} vs {}", high.quality, low.quality);
    }

    #[test]
    fn rates_decompose_consistently() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![60.0, 40.0]), span);
        let total_rate = reqs.len() as f64 / span;
        assert!((out.tier_workloads[0].rate - total_rate).abs() / total_rate < 1e-9);
        for t in 0..3 {
            let expect = total_rate * out.processing_ratios[t];
            assert!((out.tier_workloads[t].rate - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn accepting_tier_consistent_with_ratios() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![60.0, 40.0]), span);
        let frac_at_2 = out
            .accepting_tier
            .iter()
            .filter(|&&t| t == 2)
            .count() as f64
            / reqs.len() as f64;
        assert!((frac_at_2 - out.processing_ratios[2]).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "thresholds")]
    fn wrong_threshold_count_panics() {
        let (cascade, judger, reqs, span) = setup();
        route(&cascade, &judger, &reqs, &Thresholds(vec![50.0]), span);
    }

    #[test]
    fn threshold_visits_are_contiguous_from_zero() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![60.0, 40.0]), span);
        for (i, visited) in out.visited_tiers.iter().enumerate() {
            let expect: Vec<u8> = (0..=out.accepting_tier[i]).collect();
            assert_eq!(visited, &expect, "request {i}");
        }
    }

    #[test]
    fn length_policy_long_requests_skip_tier_zero() {
        let (cascade, judger, reqs, span) = setup();
        let policy = LengthPolicy::new(vec![80.0, 80.0], 600.0, 1).unwrap();
        let out = route_with(&cascade, &judger, &reqs, &policy, span).unwrap();
        let mut saw_long = false;
        for (i, req) in reqs.iter().enumerate() {
            if req.input_tokens as f64 >= 600.0 {
                saw_long = true;
                assert!(
                    !out.visited_tiers[i].contains(&0),
                    "long request {i} visited tier 0"
                );
                assert!(out.accepting_tier[i] >= 1);
            } else {
                assert_eq!(out.visited_tiers[i][0], 0);
            }
        }
        assert!(saw_long, "trace has no long requests; cutoff too high");
        // Tier 0 no longer sees everything.
        assert!(out.processing_ratios[0] < 1.0);
    }

    #[test]
    fn margin_policy_skips_intermediate_tier_on_deep_failure() {
        let (cascade, judger, reqs, span) = setup();
        let policy = MarginPolicy::new(vec![80.0, 80.0], 10.0).unwrap();
        let out = route_with(&cascade, &judger, &reqs, &policy, span).unwrap();
        // Deep failures at tier 0 (score < 70 there) jump straight to
        // tier 2 — some requests must accept at tier 2 without ever
        // visiting tier 1.
        let skipped = (0..reqs.len())
            .filter(|&i| {
                out.accepting_tier[i] == 2 && !out.visited_tiers[i].contains(&1)
            })
            .count();
        assert!(skipped > 0, "no deep failure ever skipped the middle tier");
        // Consequently tier 1 sees strictly less traffic than under the
        // plain threshold rule with the same bars.
        let plain = route(&cascade, &judger, &reqs, &Thresholds(vec![80.0, 80.0]), span);
        assert!(out.processing_ratios[1] < plain.processing_ratios[1]);
    }

    #[test]
    fn policy_spec_delegates_like_concrete_policy() {
        let (cascade, judger, reqs, span) = setup();
        let concrete = MarginPolicy::new(vec![70.0, 50.0], 20.0).unwrap();
        let spec = PolicySpec::margin(vec![70.0, 50.0], 20.0).unwrap();
        let a = route_with(&cascade, &judger, &reqs, &concrete, span).unwrap();
        let b = route_with(&cascade, &judger, &reqs, &spec, span).unwrap();
        assert_eq!(a.accepting_tier, b.accepting_tier);
        assert_eq!(a.final_scores, b.final_scores);
        assert_eq!(a.processing_ratios, b.processing_ratios);
    }
}
