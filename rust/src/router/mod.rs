//! Threshold-based cascade routing (§3.3, Figure 5).
//!
//! Every request is first served by the smallest tier; the judger
//! scores the response, and a score below threshold `h_i` forwards the
//! request to tier i+1. The last tier always accepts. Routing a
//! concrete trace yields the per-tier *processing ratios* `p_i`, the
//! per-tier workloads `w_i` consumed by the inner MILP, and the overall
//! quality metric `Q(θ)` — i.e. everything the outer optimization
//! iterates on.

use crate::judge::Judger;
use crate::models::ModelSpec;
use crate::perf::Workload;
use crate::workload::Request;

/// Routing thresholds `h_1..h_{C-1}` (score in [0, 100]; a request is
/// accepted at tier i when its score >= h_i).
#[derive(Debug, Clone, PartialEq)]
pub struct Thresholds(pub Vec<f64>);

impl Thresholds {
    pub fn uniform(c_minus_1: usize, h: f64) -> Thresholds {
        Thresholds(vec![h; c_minus_1])
    }
}

/// Result of routing one trace through the cascade.
#[derive(Debug, Clone)]
pub struct RoutingOutcome {
    /// Accepting tier index per request (aligned with the trace).
    pub accepting_tier: Vec<u8>,
    /// Fraction of requests processed by each tier (p_i; p_0 == 1).
    pub processing_ratios: Vec<f64>,
    /// Workload each tier sees (visits, not accepts).
    pub tier_workloads: Vec<Workload>,
    /// Mean judged score of the accepted responses — Q(θ).
    pub quality: f64,
    /// Judged score each request finally received.
    pub final_scores: Vec<f64>,
}

/// Route `requests` through `cascade` with `thresholds`.
///
/// `span_seconds` is the observation window used to turn visit counts
/// into rates; pass the trace's true span.
pub fn route(
    cascade: &[ModelSpec],
    judger: &Judger,
    requests: &[Request],
    thresholds: &Thresholds,
    span_seconds: f64,
) -> RoutingOutcome {
    let c = cascade.len();
    assert_eq!(
        thresholds.0.len(),
        c - 1,
        "need {} thresholds for a {}-tier cascade",
        c - 1,
        c
    );
    assert!(span_seconds > 0.0);

    let mut accepting = vec![0u8; requests.len()];
    let mut final_scores = vec![0.0f64; requests.len()];
    let mut visits = vec![0usize; c];
    let mut in_tokens = vec![0f64; c];
    let mut out_tokens = vec![0f64; c];

    for (idx, req) in requests.iter().enumerate() {
        for tier in 0..c {
            visits[tier] += 1;
            in_tokens[tier] += req.input_tokens as f64;
            out_tokens[tier] += req.output_tokens as f64;
            let score = judger.score(&cascade[tier], req, tier);
            let accepted = tier == c - 1 || score >= thresholds.0[tier];
            if accepted {
                accepting[idx] = tier as u8;
                final_scores[idx] = score;
                break;
            }
        }
    }

    let n = requests.len() as f64;
    let processing_ratios: Vec<f64> = visits.iter().map(|&v| v as f64 / n.max(1.0)).collect();
    let tier_workloads: Vec<Workload> = (0..c)
        .map(|t| Workload {
            rate: visits[t] as f64 / span_seconds,
            avg_input: if visits[t] > 0 { in_tokens[t] / visits[t] as f64 } else { 0.0 },
            avg_output: if visits[t] > 0 { out_tokens[t] / visits[t] as f64 } else { 0.0 },
        })
        .collect();
    let quality = if requests.is_empty() {
        0.0
    } else {
        final_scores.iter().sum::<f64>() / n
    };

    RoutingOutcome {
        accepting_tier: accepting,
        processing_ratios,
        tier_workloads,
        quality,
        final_scores,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::deepseek_cascade;
    use crate::workload::{generate, paper_trace};

    fn setup() -> (Vec<ModelSpec>, Judger, Vec<Request>, f64) {
        let cascade = deepseek_cascade();
        let judger = Judger::new(1);
        let reqs = generate(&paper_trace(2, 4.0), 1500, 3);
        let span = reqs.last().unwrap().arrival;
        (cascade, judger, reqs, span)
    }

    #[test]
    fn zero_thresholds_accept_everything_at_tier_one() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds::uniform(2, 0.0), span);
        assert!(out.accepting_tier.iter().all(|&t| t == 0));
        assert_eq!(out.processing_ratios, vec![1.0, 0.0, 0.0]);
        assert_eq!(out.tier_workloads[1].rate, 0.0);
    }

    #[test]
    fn max_thresholds_send_everything_to_the_top() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds::uniform(2, 101.0), span);
        assert!(out.accepting_tier.iter().all(|&t| t == 2));
        assert_eq!(out.processing_ratios, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn ratios_are_monotone_decreasing_along_cascade() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(
            &cascade,
            &judger,
            &reqs,
            &Thresholds(vec![70.0, 60.0]),
            span,
        );
        assert_eq!(out.processing_ratios[0], 1.0);
        assert!(out.processing_ratios[0] >= out.processing_ratios[1]);
        assert!(out.processing_ratios[1] >= out.processing_ratios[2]);
        assert!(out.processing_ratios[1] > 0.0);
    }

    #[test]
    fn higher_thresholds_escalate_more_and_raise_quality() {
        let (cascade, judger, reqs, span) = setup();
        let low = route(&cascade, &judger, &reqs, &Thresholds(vec![30.0, 30.0]), span);
        let high = route(&cascade, &judger, &reqs, &Thresholds(vec![85.0, 85.0]), span);
        assert!(high.processing_ratios[2] > low.processing_ratios[2]);
        assert!(high.quality > low.quality, "{} vs {}", high.quality, low.quality);
    }

    #[test]
    fn rates_decompose_consistently() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![60.0, 40.0]), span);
        let total_rate = reqs.len() as f64 / span;
        assert!((out.tier_workloads[0].rate - total_rate).abs() / total_rate < 1e-9);
        for t in 0..3 {
            let expect = total_rate * out.processing_ratios[t];
            assert!((out.tier_workloads[t].rate - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn accepting_tier_consistent_with_ratios() {
        let (cascade, judger, reqs, span) = setup();
        let out = route(&cascade, &judger, &reqs, &Thresholds(vec![60.0, 40.0]), span);
        let frac_at_2 = out
            .accepting_tier
            .iter()
            .filter(|&&t| t == 2)
            .count() as f64
            / reqs.len() as f64;
        assert!((frac_at_2 - out.processing_ratios[2]).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "thresholds")]
    fn wrong_threshold_count_panics() {
        let (cascade, judger, reqs, span) = setup();
        route(&cascade, &judger, &reqs, &Thresholds(vec![50.0]), span);
    }
}
