//! The routing-policy abstraction: cascade routing as a *family* of
//! strategies rather than one hardwired threshold rule.
//!
//! The paper's outer loop co-optimizes a routing strategy with the
//! deployment plan (§3.3); related systems show the strategy space is
//! wider than fixed thresholds — CascadeServe tunes thresholds per load
//! regime, CascadeInfer routes by predicted request length before any
//! model runs. [`RoutingPolicy`] captures the common contract:
//!
//! * [`RoutingPolicy::entry_tier`] — which tier serves the request
//!   first, decided from pre-execution [`RequestFeatures`] only;
//! * [`RoutingPolicy::decide`] — given a judged score at a tier,
//!   [`Decision::Accept`] the response, [`Decision::Escalate`] one
//!   tier up, or [`Decision::SkipTo`] a deeper tier directly.
//!
//! Three built-in implementations:
//!
//! * [`ThresholdPolicy`] — the paper's per-tier score thresholds
//!   (behavior-preserving port of the legacy `Thresholds`);
//! * [`LengthPolicy`] — length-predictive entry: requests whose prompt
//!   exceeds a cutoff bypass the small tier entirely;
//! * [`MarginPolicy`] — margin/hysteresis escalation: a near-miss
//!   escalates one tier, a deep failure skips straight to the top.
//!
//! [`PolicySpec`] is the serializable, cloneable form carried inside a
//! `CascadePlan` and a `ServerConfig`, so `cascadia schedule` output
//! feeds `cascadia serve` directly. It itself implements
//! [`RoutingPolicy`] by delegation.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::workload::Request;

/// Thresholds are judged scores in [0, 100]; 101 is the documented
/// "always escalate" sentinel used by the utopia point and the
/// standalone baseline.
pub const THRESHOLD_MAX: f64 = 101.0;

/// Pre-execution request features available to a policy. On the live
/// path only the prompt length is observable; `complexity` is the
/// synthetic traces' latent difficulty and is NaN when unknown, so
/// policies must not rely on it for live-serving parity.
#[derive(Debug, Clone, Copy)]
pub struct RequestFeatures {
    pub input_tokens: u32,
    /// Expected/observed output length; 0 when unknown (live path).
    pub output_tokens: u32,
    /// Latent difficulty in [0, 1]; NaN on the live path.
    pub complexity: f64,
}

impl RequestFeatures {
    /// Features of an offline trace request.
    pub fn of(req: &Request) -> RequestFeatures {
        RequestFeatures {
            input_tokens: req.input_tokens,
            output_tokens: req.output_tokens,
            complexity: req.complexity,
        }
    }

    /// Features of a live request: only the prompt length is known.
    pub fn live(prompt_tokens: usize) -> RequestFeatures {
        RequestFeatures {
            input_tokens: prompt_tokens.min(u32::MAX as usize) as u32,
            output_tokens: 0,
            complexity: f64::NAN,
        }
    }
}

/// A policy's verdict on a scored response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// The response is good enough; the request completes here.
    Accept,
    /// Forward to the next tier.
    Escalate,
    /// Jump to a deeper tier (must be strictly beyond the current one).
    SkipTo(usize),
}

/// A cascade routing strategy. Implementations must be deterministic
/// in their inputs so offline routing, the simulators, and the live
/// server agree on every decision.
pub trait RoutingPolicy: Send + Sync {
    /// Tier at which a request enters the cascade (before any model
    /// runs). Defaults to the smallest tier.
    fn entry_tier(&self, _features: &RequestFeatures, _n_tiers: usize) -> usize {
        0
    }

    /// Decide what happens to a response scored `score` at `tier`.
    /// Never called for the last tier — it always accepts.
    fn decide(&self, tier: usize, score: f64, features: &RequestFeatures, n_tiers: usize)
        -> Decision;

    /// Check the policy's parameters against a cascade size.
    fn validate(&self, n_tiers: usize) -> Result<()>;

    /// Human-readable parameter summary (used in plan summaries/logs).
    fn label(&self) -> String;
}

/// Validate a per-tier threshold vector: finite, within
/// [0, [`THRESHOLD_MAX`]], one entry per non-final tier.
fn validate_thresholds(thresholds: &[f64], n_tiers: usize) -> Result<()> {
    if n_tiers == 0 {
        bail!("cascade must have at least one tier");
    }
    if thresholds.len() + 1 != n_tiers {
        bail!(
            "need {} thresholds for a {}-tier cascade, got {}",
            n_tiers - 1,
            n_tiers,
            thresholds.len()
        );
    }
    check_threshold_values(thresholds)
}

fn check_threshold_values(thresholds: &[f64]) -> Result<()> {
    for (i, &h) in thresholds.iter().enumerate() {
        if !h.is_finite() || !(0.0..=THRESHOLD_MAX).contains(&h) {
            bail!("threshold h{} = {h} outside [0, {THRESHOLD_MAX}]", i + 1);
        }
    }
    Ok(())
}

fn fmt_thresholds(thresholds: &[f64]) -> String {
    let h = thresholds
        .iter()
        .map(|h| format!("{h:.0}"))
        .collect::<Vec<_>>()
        .join(",");
    format!("H=({h})")
}

/// The paper's routing rule (§3.3, Figure 5): a request is accepted at
/// tier i when its judged score reaches `h_i`; the last tier always
/// accepts.
#[derive(Debug, Clone, PartialEq)]
pub struct ThresholdPolicy {
    thresholds: Vec<f64>,
}

impl ThresholdPolicy {
    /// Construct with validated parameters (finite, within
    /// [0, [`THRESHOLD_MAX`]]). Arity is checked against the cascade at
    /// routing/serving time via [`RoutingPolicy::validate`].
    pub fn new(thresholds: Vec<f64>) -> Result<ThresholdPolicy> {
        check_threshold_values(&thresholds)?;
        Ok(ThresholdPolicy { thresholds })
    }

    /// The same threshold at every tier boundary.
    pub fn uniform(c_minus_1: usize, h: f64) -> Result<ThresholdPolicy> {
        ThresholdPolicy::new(vec![h; c_minus_1])
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }
}

impl RoutingPolicy for ThresholdPolicy {
    fn decide(
        &self,
        tier: usize,
        score: f64,
        _features: &RequestFeatures,
        n_tiers: usize,
    ) -> Decision {
        if tier + 1 >= n_tiers || score >= self.thresholds[tier] {
            Decision::Accept
        } else {
            Decision::Escalate
        }
    }

    fn validate(&self, n_tiers: usize) -> Result<()> {
        validate_thresholds(&self.thresholds, n_tiers)
    }

    fn label(&self) -> String {
        fmt_thresholds(&self.thresholds)
    }
}

/// Length-predictive routing (CascadeInfer-style): requests whose
/// prompt length reaches `length_cutoff` are predicted hard and enter
/// the cascade at `entry_tier`, bypassing the smaller tiers; everything
/// else follows the threshold rule from tier 0.
#[derive(Debug, Clone, PartialEq)]
pub struct LengthPolicy {
    thresholds: Vec<f64>,
    length_cutoff: f64,
    entry_tier: usize,
}

impl LengthPolicy {
    pub fn new(thresholds: Vec<f64>, length_cutoff: f64, entry_tier: usize) -> Result<LengthPolicy> {
        check_threshold_values(&thresholds)?;
        if !length_cutoff.is_finite() || length_cutoff <= 0.0 {
            bail!("length_cutoff must be a positive finite token count, got {length_cutoff}");
        }
        if entry_tier == 0 {
            bail!("entry_tier 0 makes the length predictor a no-op; use ThresholdPolicy");
        }
        Ok(LengthPolicy { thresholds, length_cutoff, entry_tier })
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }

    pub fn length_cutoff(&self) -> f64 {
        self.length_cutoff
    }

    pub fn target_tier(&self) -> usize {
        self.entry_tier
    }
}

impl RoutingPolicy for LengthPolicy {
    fn entry_tier(&self, features: &RequestFeatures, n_tiers: usize) -> usize {
        if features.input_tokens as f64 >= self.length_cutoff {
            self.entry_tier.min(n_tiers - 1)
        } else {
            0
        }
    }

    fn decide(
        &self,
        tier: usize,
        score: f64,
        _features: &RequestFeatures,
        n_tiers: usize,
    ) -> Decision {
        if tier + 1 >= n_tiers || score >= self.thresholds[tier] {
            Decision::Accept
        } else {
            Decision::Escalate
        }
    }

    fn validate(&self, n_tiers: usize) -> Result<()> {
        validate_thresholds(&self.thresholds, n_tiers)?;
        if self.entry_tier >= n_tiers {
            bail!(
                "entry_tier {} out of range for a {}-tier cascade",
                self.entry_tier,
                n_tiers
            );
        }
        Ok(())
    }

    fn label(&self) -> String {
        format!(
            "len>={:.0}->T{} {}",
            self.length_cutoff,
            self.entry_tier + 1,
            fmt_thresholds(&self.thresholds)
        )
    }
}

/// Margin/hysteresis escalation: scores at or above `h_i` accept; a
/// near-miss inside the margin band `[h_i - margin, h_i)` escalates
/// one tier (the next model is probably enough); a deep failure below
/// the band skips the intermediate tiers and goes straight to the
/// strongest model, saving the wasted middle-tier visit.
#[derive(Debug, Clone, PartialEq)]
pub struct MarginPolicy {
    thresholds: Vec<f64>,
    margin: f64,
}

impl MarginPolicy {
    pub fn new(thresholds: Vec<f64>, margin: f64) -> Result<MarginPolicy> {
        check_threshold_values(&thresholds)?;
        if !margin.is_finite() || margin < 0.0 {
            bail!("margin must be a finite non-negative score delta, got {margin}");
        }
        Ok(MarginPolicy { thresholds, margin })
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }

    pub fn margin(&self) -> f64 {
        self.margin
    }
}

impl RoutingPolicy for MarginPolicy {
    fn decide(
        &self,
        tier: usize,
        score: f64,
        _features: &RequestFeatures,
        n_tiers: usize,
    ) -> Decision {
        if tier + 1 >= n_tiers {
            return Decision::Accept;
        }
        let h = self.thresholds[tier];
        if score >= h {
            Decision::Accept
        } else if score < h - self.margin {
            // Deep failure: the next tier up is unlikely to clear the
            // bar either; go straight to the top.
            Decision::SkipTo(n_tiers - 1)
        } else {
            Decision::Escalate
        }
    }

    fn validate(&self, n_tiers: usize) -> Result<()> {
        validate_thresholds(&self.thresholds, n_tiers)
    }

    fn label(&self) -> String {
        format!("{} margin={:.0}", fmt_thresholds(&self.thresholds), self.margin)
    }
}

/// The policy families the scheduler can sweep and the plan/server can
/// carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Threshold,
    Length,
    Margin,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "threshold" => Ok(PolicyKind::Threshold),
            "length" => Ok(PolicyKind::Length),
            "margin" => Ok(PolicyKind::Margin),
            other => bail!("unknown policy kind '{other}' (expected threshold|length|margin)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Threshold => "threshold",
            PolicyKind::Length => "length",
            PolicyKind::Margin => "margin",
        }
    }
}

/// Serializable routing policy: the concrete parameters of one of the
/// built-in families. This is what `CascadePlan` stores, `to_json`
/// round-trips, and `ServerConfig`/`TcpFrontend` execute.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    Threshold(ThresholdPolicy),
    Length(LengthPolicy),
    Margin(MarginPolicy),
}

impl PolicySpec {
    pub fn threshold(thresholds: Vec<f64>) -> Result<PolicySpec> {
        Ok(PolicySpec::Threshold(ThresholdPolicy::new(thresholds)?))
    }

    pub fn uniform_threshold(c_minus_1: usize, h: f64) -> Result<PolicySpec> {
        Ok(PolicySpec::Threshold(ThresholdPolicy::uniform(c_minus_1, h)?))
    }

    pub fn length(thresholds: Vec<f64>, cutoff: f64, entry_tier: usize) -> Result<PolicySpec> {
        Ok(PolicySpec::Length(LengthPolicy::new(thresholds, cutoff, entry_tier)?))
    }

    pub fn margin(thresholds: Vec<f64>, margin: f64) -> Result<PolicySpec> {
        Ok(PolicySpec::Margin(MarginPolicy::new(thresholds, margin)?))
    }

    pub fn kind(&self) -> PolicyKind {
        match self {
            PolicySpec::Threshold(_) => PolicyKind::Threshold,
            PolicySpec::Length(_) => PolicyKind::Length,
            PolicySpec::Margin(_) => PolicyKind::Margin,
        }
    }

    /// Per-tier acceptance thresholds — every built-in family carries
    /// them, so tables/figures can report h_i uniformly.
    pub fn thresholds(&self) -> &[f64] {
        match self {
            PolicySpec::Threshold(p) => p.thresholds(),
            PolicySpec::Length(p) => p.thresholds(),
            PolicySpec::Margin(p) => p.thresholds(),
        }
    }

    /// Serialize to the plan-JSON policy object.
    pub fn to_json(&self) -> Json {
        let thresholds = Json::arr(self.thresholds().iter().map(|&h| Json::num(h)).collect());
        match self {
            PolicySpec::Threshold(_) => Json::obj(vec![
                ("kind", Json::str("threshold")),
                ("thresholds", thresholds),
            ]),
            PolicySpec::Length(p) => Json::obj(vec![
                ("kind", Json::str("length")),
                ("thresholds", thresholds),
                ("length_cutoff", Json::num(p.length_cutoff())),
                ("entry_tier", Json::num(p.target_tier() as f64)),
            ]),
            PolicySpec::Margin(p) => Json::obj(vec![
                ("kind", Json::str("margin")),
                ("thresholds", thresholds),
                ("margin", Json::num(p.margin())),
            ]),
        }
    }

    /// Parse the plan-JSON policy object back.
    pub fn from_json(j: &Json) -> Result<PolicySpec> {
        let kind = PolicyKind::parse(j.req("kind")?.as_str()?)?;
        let thresholds: Vec<f64> = j
            .req("thresholds")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Result<_>>()
            .context("policy thresholds")?;
        match kind {
            PolicyKind::Threshold => PolicySpec::threshold(thresholds),
            PolicyKind::Length => PolicySpec::length(
                thresholds,
                j.req("length_cutoff")?.as_f64()?,
                j.req("entry_tier")?.as_usize()?,
            ),
            PolicyKind::Margin => PolicySpec::margin(thresholds, j.req("margin")?.as_f64()?),
        }
    }
}

impl RoutingPolicy for PolicySpec {
    fn entry_tier(&self, features: &RequestFeatures, n_tiers: usize) -> usize {
        match self {
            PolicySpec::Threshold(p) => p.entry_tier(features, n_tiers),
            PolicySpec::Length(p) => p.entry_tier(features, n_tiers),
            PolicySpec::Margin(p) => p.entry_tier(features, n_tiers),
        }
    }

    fn decide(&self, tier: usize, score: f64, features: &RequestFeatures, n_tiers: usize)
        -> Decision {
        match self {
            PolicySpec::Threshold(p) => p.decide(tier, score, features, n_tiers),
            PolicySpec::Length(p) => p.decide(tier, score, features, n_tiers),
            PolicySpec::Margin(p) => p.decide(tier, score, features, n_tiers),
        }
    }

    fn validate(&self, n_tiers: usize) -> Result<()> {
        match self {
            PolicySpec::Threshold(p) => p.validate(n_tiers),
            PolicySpec::Length(p) => p.validate(n_tiers),
            PolicySpec::Margin(p) => p.validate(n_tiers),
        }
    }

    fn label(&self) -> String {
        match self {
            PolicySpec::Threshold(p) => p.label(),
            PolicySpec::Length(p) => p.label(),
            PolicySpec::Margin(p) => p.label(),
        }
    }
}

/// All monotone non-increasing chains of length `len` over `grid` —
/// the shared parameter enumeration of every threshold-bearing family
/// (escalating to a bigger model with a *stricter* bar than the
/// previous tier wastes evaluations; the paper's Table 1 thresholds
/// are all monotone).
pub fn monotone_chains(grid: &[f64], len: usize) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let mut stack: Vec<Vec<f64>> = vec![vec![]];
    while let Some(prefix) = stack.pop() {
        if prefix.len() == len {
            out.push(prefix);
            continue;
        }
        let cap = prefix.last().copied().unwrap_or(f64::INFINITY);
        for &h in grid.iter().filter(|&&h| h <= cap) {
            let mut next = prefix.clone();
            next.push(h);
            stack.push(next);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(input: u32) -> RequestFeatures {
        RequestFeatures { input_tokens: input, output_tokens: 0, complexity: f64::NAN }
    }

    #[test]
    fn threshold_policy_matches_legacy_rule() {
        let p = ThresholdPolicy::new(vec![70.0, 50.0]).unwrap();
        p.validate(3).unwrap();
        assert_eq!(p.decide(0, 70.0, &f(10), 3), Decision::Accept);
        assert_eq!(p.decide(0, 69.9, &f(10), 3), Decision::Escalate);
        assert_eq!(p.decide(1, 49.0, &f(10), 3), Decision::Escalate);
        // Last tier always accepts.
        assert_eq!(p.decide(2, 0.0, &f(10), 3), Decision::Accept);
        assert_eq!(p.entry_tier(&f(10_000), 3), 0);
    }

    #[test]
    fn construction_rejects_bad_parameters() {
        assert!(ThresholdPolicy::new(vec![f64::NAN]).is_err());
        assert!(ThresholdPolicy::new(vec![-1.0]).is_err());
        assert!(ThresholdPolicy::new(vec![102.0]).is_err());
        assert!(ThresholdPolicy::new(vec![101.0]).is_ok()); // sentinel allowed
        assert!(LengthPolicy::new(vec![80.0], 0.0, 1).is_err());
        assert!(LengthPolicy::new(vec![80.0], f64::INFINITY, 1).is_err());
        assert!(LengthPolicy::new(vec![80.0], 900.0, 0).is_err());
        assert!(MarginPolicy::new(vec![80.0], -5.0).is_err());
        assert!(MarginPolicy::new(vec![80.0], f64::NAN).is_err());
    }

    #[test]
    fn arity_validated_against_cascade() {
        let p = ThresholdPolicy::new(vec![70.0]).unwrap();
        assert!(p.validate(2).is_ok());
        let err = p.validate(3).unwrap_err().to_string();
        assert!(err.contains("thresholds"), "{err}");
        let l = LengthPolicy::new(vec![70.0, 50.0], 900.0, 5).unwrap();
        assert!(l.validate(3).is_err()); // entry tier out of range
    }

    #[test]
    fn length_policy_bypasses_small_tier_for_long_prompts() {
        let p = LengthPolicy::new(vec![80.0, 80.0], 900.0, 1).unwrap();
        assert_eq!(p.entry_tier(&f(100), 3), 0);
        assert_eq!(p.entry_tier(&f(900), 3), 1);
        assert_eq!(p.entry_tier(&f(4000), 3), 1);
        // Entry tier is clamped to the cascade.
        let top = LengthPolicy::new(vec![80.0], 900.0, 9).unwrap();
        assert_eq!(top.entry_tier(&f(4000), 2), 1);
    }

    #[test]
    fn margin_policy_escalates_near_misses_and_skips_deep_failures() {
        let p = MarginPolicy::new(vec![80.0, 60.0], 15.0).unwrap();
        assert_eq!(p.decide(0, 85.0, &f(10), 3), Decision::Accept);
        assert_eq!(p.decide(0, 70.0, &f(10), 3), Decision::Escalate); // near miss
        assert_eq!(p.decide(0, 30.0, &f(10), 3), Decision::SkipTo(2)); // deep failure
        // From the second-to-last tier a skip targets the same place
        // escalation would.
        assert_eq!(p.decide(1, 10.0, &f(10), 3), Decision::SkipTo(2));
    }

    #[test]
    fn spec_json_roundtrip_all_kinds() {
        let specs = [
            PolicySpec::threshold(vec![70.0, 50.0]).unwrap(),
            PolicySpec::length(vec![80.0, 60.0], 900.0, 1).unwrap(),
            PolicySpec::margin(vec![80.0, 60.0], 15.0).unwrap(),
        ];
        for spec in specs {
            let text = spec.to_json().to_string();
            let back = PolicySpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, spec, "{text}");
            assert_eq!(back.kind(), spec.kind());
        }
    }

    #[test]
    fn spec_json_rejects_garbage() {
        let j = Json::parse(r#"{"kind": "alchemy", "thresholds": [50]}"#).unwrap();
        assert!(PolicySpec::from_json(&j).is_err());
        let j = Json::parse(r#"{"kind": "length", "thresholds": [50]}"#).unwrap();
        assert!(PolicySpec::from_json(&j).is_err()); // missing cutoff/entry
        let j = Json::parse(r#"{"kind": "threshold", "thresholds": [500]}"#).unwrap();
        assert!(PolicySpec::from_json(&j).is_err()); // out of range
    }

    #[test]
    fn monotone_chain_enumeration() {
        let chains = monotone_chains(&[0.0, 50.0, 100.0], 2);
        // 3 + 2 + 1 monotone pairs.
        assert_eq!(chains.len(), 6);
        for c in &chains {
            assert!(c[0] >= c[1], "{c:?}");
        }
        assert_eq!(monotone_chains(&[0.0, 50.0], 1).len(), 2);
        assert_eq!(monotone_chains(&[0.0], 0), vec![Vec::<f64>::new()]);
    }

    #[test]
    fn labels_are_informative() {
        assert_eq!(PolicySpec::threshold(vec![70.0, 50.0]).unwrap().label(), "H=(70,50)");
        let l = PolicySpec::length(vec![70.0], 900.0, 1).unwrap().label();
        assert!(l.contains("len>=900") && l.contains("T2"), "{l}");
        let m = PolicySpec::margin(vec![70.0], 15.0).unwrap().label();
        assert!(m.contains("margin=15"), "{m}");
    }
}
