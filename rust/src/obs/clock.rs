//! The clock boundary: wall vs simulated time behind one `now()`.
//!
//! This is the ONLY file under `obs/` permitted to read
//! `Instant::now` — the `determinism` lint bans raw wall-clock reads
//! everywhere else in the module, so the tracing path shared with the
//! DES stays deterministic by construction. Everything downstream of a
//! [`Clock`] sees only `f64` seconds since an epoch:
//!
//! * [`Clock::wall`] — seconds since construction (or an explicit
//!   [`Instant`] epoch, so a server can stamp events on the same
//!   timeline as its existing `t0.elapsed()` accounting);
//! * [`Clock::manual`] — a settable simulated time, advanced by the
//!   DES event loop (and by tests).
//!
//! Contract: `now()` is monotone non-decreasing for wall clocks; for
//! manual clocks it returns exactly what [`Clock::set`] last stored
//! (the DES sets it to the simulation's `now` before emitting).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::sync::LockExt;

/// A source of event timestamps: wall time or simulated time.
#[derive(Debug, Clone)]
pub enum Clock {
    /// Wall clock: `now()` = seconds since the stored epoch.
    Wall(Instant),
    /// Simulated clock: `now()` = the last value stored by `set`.
    /// Shared, so the DES loop and its emitters see one timeline.
    Manual(Arc<Mutex<f64>>),
}

impl Clock {
    /// A wall clock whose epoch is "now".
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    /// A wall clock with an explicit epoch (share a server's `t0` so
    /// trace timestamps align with its latency accounting).
    pub fn wall_from(epoch: Instant) -> Clock {
        Clock::Wall(epoch)
    }

    /// A simulated clock starting at 0.
    pub fn manual() -> Clock {
        Clock::Manual(Arc::new(Mutex::new(0.0)))
    }

    /// Seconds since the epoch.
    pub fn now(&self) -> f64 {
        match self {
            Clock::Wall(epoch) => epoch.elapsed().as_secs_f64(),
            Clock::Manual(t) => *t.plock(),
        }
    }

    /// Advance a simulated clock. Panics on a wall clock — simulated
    /// time cannot be injected into a wall timeline; that would forge
    /// timestamps.
    pub fn set(&self, t: f64) {
        match self {
            Clock::Wall(_) => panic!("cannot set a wall clock"),
            Clock::Manual(cell) => *cell.plock() = t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = Clock::wall();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn manual_clock_returns_what_was_set() {
        let c = Clock::manual();
        assert_eq!(c.now(), 0.0);
        c.set(12.5);
        assert_eq!(c.now(), 12.5);
        // Clones share the timeline (DES loop + emitters).
        let c2 = c.clone();
        c2.set(99.0);
        assert_eq!(c.now(), 99.0);
    }

    #[test]
    #[should_panic(expected = "cannot set a wall clock")]
    fn wall_clock_rejects_set() {
        Clock::wall().set(1.0);
    }
}
