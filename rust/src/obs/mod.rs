//! Request-lifecycle observability: structured tracing + unified
//! metrics for the serving stack.
//!
//! Cascadia's adaptation loop (monitor → re-schedule → hot-swap) is
//! driven by workload telemetry, and the DES↔live-engine equivalence
//! pins compare execution timelines — both need one shared event
//! schema instead of ad-hoc counters. This module provides it:
//!
//! * [`recorder::TraceRecorder`] — a bounded, sharded ring buffer of
//!   fixed-size [`Event`]s (drop-oldest on overflow, counted; zero
//!   allocation on the emit path);
//! * [`clock::Clock`] — wall vs simulated time behind one `now() ->
//!   f64` surface, so the DES emits the *same schema* at simulated
//!   timestamps. `clock.rs` is the only obs file permitted to read
//!   `Instant::now` (the `determinism` lint enforces this);
//! * [`registry::MetricsRegistry`] — counters/gauges/fixed-bucket
//!   histograms with Prometheus text exposition, from which the serve
//!   loop's latency reporting is derived;
//! * [`chrome`] — Chrome trace-event JSON export (Perfetto-loadable);
//! * [`diff`] — per-request timeline alignment between two traces,
//!   reporting the first divergence (the DES↔live pin surface).
//!
//! ## Event vocabulary
//!
//! Every event is a fixed-size record keyed by a **global request id**
//! (`req`), so escalation chains link across tiers. Integer payloads
//! live in `a`/`b`/`c`, float payloads in `fa`/`fb`:
//!
//! | kind            | emitted by        | payload |
//! |-----------------|-------------------|---------|
//! | `admitted`      | server submitter  | `a` = entry tier |
//! | `queue_enter`   | server submitter  | `tier` = queue joined |
//! | `queue_exit`    | tier worker       | `tier` = queue left |
//! | `route_decision`| server router     | `a` = action (0 accept / 1 escalate / 2 skip), `b` = target tier |
//! | `prefill_chunk` | engine / DES plan | `a` = tokens, `b` = start offset, `c` = last flag. A request whose *first* chunk has `b > 0` had `b` prompt tokens served from shared prefix pages |
//! | `decode_iter`   | engine / DES plan | `a` = live batch size that tick, `b` = tokens produced (0 for legacy single-token decode; a speculative verify step re-emits this kind with `b` = accepted + 1) |
//! | `draft_iter`    | engine / DES plan | speculative draft scheduled: `a` = draft tokens `k`, `b` = live batch size that tick |
//! | `verify_accept` | engine / DES exec | speculative verify settled: `a` = draft tokens accepted, `b` = rejected |
//! | `preempt`       | engine / DES plan | recompute eviction (`a` = 0); swap evictions appear as `swap_out` instead |
//! | `swap_out`      | engine / DES plan | `a` = KV pages moved to host |
//! | `swap_in`       | engine / DES plan | `a` = KV pages moved back |
//! | `migrate_out`   | engine / DES plan | prefill→decode handoff left this engine; `a` = private KV pages sent over the interconnect |
//! | `migrate_in`    | engine / DES plan | migrated sequence admitted on the decode side; `a` = private KV pages received (shared prefix pages re-claim locally and are not counted) |
//! | `escalate`      | server router     | `a` = from tier, `b` = to tier |
//! | `hot_swap_applied` | serve loop     | `a` = swap ordinal; `req` = [`REQ_NONE`] |
//! | `finished`      | terminal authority| `fa` = TTFT s, `fb` = e2e latency s |
//!
//! Exactly one `finished` per admitted request: the emitter is the
//! *terminal authority* — the cascade router when a full server runs
//! (a request may traverse several engines), the engine itself when it
//! is driven standalone ([`EngineTracer::terminal`]), the DES at
//! retire.
//!
//! Engine-tick events (`prefill_chunk`, `decode_iter`, `preempt`,
//! `swap_out/in`) are a **pure function of the
//! [`IterationPlan`](crate::engine::scheduler::IterationPlan)**
//! ([`emit_plan_events`]), and the DES drives the same
//! `IterationScheduler` as the live engine — so the per-request event
//! sequence is identical on both sides by construction, and
//! equivalence becomes a timeline diff ([`diff::diff_timelines`]).

pub mod alert;
pub mod chrome;
pub mod clock;
pub mod diff;
pub mod profile;
pub mod recorder;
pub mod registry;

use std::sync::Arc;

use crate::engine::kv::SeqId;
use crate::engine::scheduler::IterationPlan;

pub use alert::{Alert, AlertEvaluator, AlertKind, AlertPolicy, Severity, SloBurnConfig, SloBurnMonitor};
pub use chrome::chrome_trace;
pub use clock::Clock;
pub use diff::{diff_timelines, DiffReport};
pub use profile::{Phase, ProfileAggregator, ProfileConfig, ProfileReport, Waterfall};
pub use recorder::TraceRecorder;
pub use registry::{MetricsRegistry, LATENCY_BUCKETS};

/// Export recorder health into the registry: aggregate event/drop
/// gauges plus per-shard drop counters and ring-occupancy gauges, so
/// silent span loss is visible on `/metrics` instead of only in
/// [`TraceRecorder::snapshot`].
pub fn export_recorder_health(recorder: &TraceRecorder, registry: &MetricsRegistry) {
    registry.gauge_set("cascadia_trace_events", recorder.n_events() as f64);
    registry.gauge_set("cascadia_trace_dropped_events", recorder.dropped_events() as f64);
    for (shard, st) in recorder.shard_stats().iter().enumerate() {
        registry.counter_set(
            &format!("cascadia_trace_dropped_events_total{{shard=\"{shard}\"}}"),
            st.dropped,
        );
        let occ = if st.cap == 0 { 0.0 } else { st.retained as f64 / st.cap as f64 };
        registry.gauge_set(&format!("cascadia_trace_ring_occupancy{{shard=\"{shard}\"}}"), occ);
    }
}

/// `req` value for events not tied to any request (e.g.
/// `hot_swap_applied`).
pub const REQ_NONE: u64 = u64::MAX;

/// The fixed event vocabulary. See the module docs for emitters and
/// payload conventions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    Admitted,
    QueueEnter,
    QueueExit,
    RouteDecision,
    PrefillChunk,
    DecodeIter,
    DraftIter,
    VerifyAccept,
    Preempt,
    SwapOut,
    SwapIn,
    MigrateOut,
    MigrateIn,
    Escalate,
    HotSwapApplied,
    Finished,
}

impl EventKind {
    /// Stable wire/export name of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::QueueEnter => "queue_enter",
            EventKind::QueueExit => "queue_exit",
            EventKind::RouteDecision => "route_decision",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::DecodeIter => "decode_iter",
            EventKind::DraftIter => "draft_iter",
            EventKind::VerifyAccept => "verify_accept",
            EventKind::Preempt => "preempt",
            EventKind::SwapOut => "swap_out",
            EventKind::SwapIn => "swap_in",
            EventKind::MigrateOut => "migrate_out",
            EventKind::MigrateIn => "migrate_in",
            EventKind::Escalate => "escalate",
            EventKind::HotSwapApplied => "hot_swap_applied",
            EventKind::Finished => "finished",
        }
    }

    /// Terminal events end a request's span — exactly one per admitted
    /// request.
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventKind::Finished)
    }
}

/// `route_decision` action codes (payload `a`).
pub const ACTION_ACCEPT: u64 = 0;
pub const ACTION_ESCALATE: u64 = 1;
pub const ACTION_SKIP: u64 = 2;

/// One fixed-size trace record. `Copy`, no heap payload — the ring
/// buffer never allocates after construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Global emission order (assigned by the recorder).
    pub seq: u64,
    /// Seconds since the recorder's epoch — wall or simulated,
    /// depending on the emitting [`Clock`].
    pub t: f64,
    /// Global request id ([`REQ_NONE`] for system events).
    pub req: u64,
    /// Tier the event happened on.
    pub tier: u32,
    pub kind: EventKind,
    /// Integer payloads (see the vocabulary table).
    pub a: u64,
    pub b: u64,
    pub c: u64,
    /// Float payloads (see the vocabulary table).
    pub fa: f64,
    pub fb: f64,
}

impl Event {
    /// A zero-payload event at time `t`; set `a`/`b`/`c`/`fa`/`fb` via
    /// struct update. `seq` is assigned at emit.
    pub fn at(t: f64, req: u64, tier: u32, kind: EventKind) -> Event {
        Event { seq: 0, t, req, tier, kind, a: 0, b: 0, c: 0, fa: 0.0, fb: 0.0 }
    }

    /// The structural signature compared by the timeline diff: kind +
    /// integer payloads, but NOT timestamps, float payloads, or `seq`
    /// (wall and simulated clocks legitimately differ).
    pub fn signature(&self) -> (EventKind, u64, u64, u64) {
        (self.kind, self.a, self.b, self.c)
    }
}

/// Everything an engine (or the DES) needs to emit into a shared
/// recorder: the shard it owns, the tier it serves, the clock that
/// stamps its events, and whether it is the terminal authority for
/// `finished` events (true standalone, false under a cascade router —
/// the router then owns the single terminal event per request).
#[derive(Clone)]
pub struct EngineTracer {
    pub recorder: Arc<TraceRecorder>,
    pub shard: usize,
    pub tier: u32,
    pub clock: Clock,
    pub terminal: bool,
}

impl EngineTracer {
    /// Standalone tracer on shard 0 / tier 0 with a wall clock —
    /// what a directly-driven engine uses.
    pub fn standalone(recorder: Arc<TraceRecorder>) -> EngineTracer {
        EngineTracer {
            recorder,
            shard: 0,
            tier: 0,
            clock: Clock::wall(),
            terminal: true,
        }
    }

    /// Emit one event on this tracer's shard at clock-now.
    pub fn emit(&self, req: u64, kind: EventKind, a: u64, b: u64, c: u64) {
        let t = self.clock.now();
        self.recorder.emit(
            self.shard,
            Event { a, b, c, ..Event::at(t, req, self.tier, kind) },
        );
    }

    /// Emit the terminal `finished` event (only when this tracer is
    /// the terminal authority).
    pub fn emit_finished(&self, req: u64, ttft_s: f64, latency_s: f64) {
        if !self.terminal {
            return;
        }
        let t = self.clock.now();
        self.recorder.emit(
            self.shard,
            Event { fa: ttft_s, fb: latency_s, ..Event::at(t, req, self.tier, EventKind::Finished) },
        );
    }
}

/// Emit the engine-tick events of one [`IterationPlan`] at time `t`.
///
/// This is deliberately a pure function of the plan (plus a
/// `SeqId → global request id` mapping): the live engine calls it from
/// [`EngineCore::step`](crate::engine::EngineCore::step) and the paged
/// DES calls it when it starts the same iteration, so both sides emit
/// identical per-request event sequences for identical plans — the
/// invariant `cascadia trace --diff` checks.
pub fn emit_plan_events(
    recorder: &TraceRecorder,
    shard: usize,
    t: f64,
    tier: u32,
    plan: &IterationPlan,
    key_of: impl Fn(SeqId) -> u64,
) {
    // Handoffs leave before anything else happens in a tick (scheduler
    // stage -1), so they lead the emission order.
    for &(id, pages) in &plan.migrated_out {
        recorder.emit(
            shard,
            Event { a: pages as u64, ..Event::at(t, key_of(id), tier, EventKind::MigrateOut) },
        );
    }
    for &id in &plan.preempted {
        recorder.emit(shard, Event::at(t, key_of(id), tier, EventKind::Preempt));
    }
    for &(id, pages) in &plan.swapped_out {
        recorder.emit(
            shard,
            Event { a: pages as u64, ..Event::at(t, key_of(id), tier, EventKind::SwapOut) },
        );
    }
    for &(id, pages) in &plan.swapped_in {
        recorder.emit(
            shard,
            Event { a: pages as u64, ..Event::at(t, key_of(id), tier, EventKind::SwapIn) },
        );
    }
    // Migrated-in admissions land after swap resumes (scheduler stage
    // 1.75) and decode this very tick — their decode_iter follows below.
    for &(id, pages) in &plan.migrated_in {
        recorder.emit(
            shard,
            Event { a: pages as u64, ..Event::at(t, key_of(id), tier, EventKind::MigrateIn) },
        );
    }
    for chunk in &plan.prefill {
        recorder.emit(
            shard,
            Event {
                a: chunk.len as u64,
                b: chunk.start as u64,
                c: chunk.last as u64,
                ..Event::at(t, key_of(chunk.id), tier, EventKind::PrefillChunk)
            },
        );
    }
    let batch = plan.batch() as u64;
    for &id in &plan.decode {
        recorder.emit(
            shard,
            Event { a: batch, ..Event::at(t, key_of(id), tier, EventKind::DecodeIter) },
        );
    }
    // Speculative tasks trail the plain decoders; a legacy plan has an
    // empty `spec` list, so legacy emission stays byte-identical. The
    // settled accept/reject split is emitted post-execution through
    // [`emit_spec_events`] — acceptance is not a function of the plan.
    for task in &plan.spec {
        recorder.emit(
            shard,
            Event {
                a: task.k as u64,
                b: batch,
                ..Event::at(t, key_of(task.id), tier, EventKind::DraftIter)
            },
        );
    }
}

/// One settled speculative task, as the engine (or the DES) resolved
/// it: `drafted` tokens were proposed (0 when the backend declined and
/// the task degraded to a plain decode step), `accepted` of them were
/// kept, and `emitted` verified tokens landed on the sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecResult {
    pub id: SeqId,
    pub drafted: usize,
    pub accepted: usize,
    pub emitted: usize,
}

/// Emit the post-execution events of a tick's settled speculative
/// tasks at time `t`: per task a `verify_accept` (`a` = accepted, `b` =
/// rejected) followed by a `decode_iter` whose `b` carries the tokens
/// the verify step produced (legacy single-token decodes keep `b` = 0,
/// so their signatures are untouched). Like [`emit_plan_events`] this
/// is a pure function of its inputs and is called identically by the
/// live engine and the paged DES — acceptance counts join the
/// tick-for-tick equivalence pin through it. Tasks that degraded to a
/// plain decode (`drafted == 0`) emit only the legacy-shaped
/// `decode_iter`.
pub fn emit_spec_events(
    recorder: &TraceRecorder,
    shard: usize,
    t: f64,
    tier: u32,
    batch: usize,
    results: &[SpecResult],
    key_of: impl Fn(SeqId) -> u64,
) {
    for r in results {
        let req = key_of(r.id);
        if r.drafted > 0 {
            recorder.emit(
                shard,
                Event {
                    a: r.accepted as u64,
                    b: (r.drafted - r.accepted.min(r.drafted)) as u64,
                    ..Event::at(t, req, tier, EventKind::VerifyAccept)
                },
            );
            recorder.emit(
                shard,
                Event {
                    a: batch as u64,
                    b: r.emitted as u64,
                    ..Event::at(t, req, tier, EventKind::DecodeIter)
                },
            );
        } else {
            recorder.emit(
                shard,
                Event { a: batch as u64, ..Event::at(t, req, tier, EventKind::DecodeIter) },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::scheduler::ChunkTask;

    #[test]
    fn kind_names_are_stable_and_unique() {
        let kinds = [
            EventKind::Admitted,
            EventKind::QueueEnter,
            EventKind::QueueExit,
            EventKind::RouteDecision,
            EventKind::PrefillChunk,
            EventKind::DecodeIter,
            EventKind::DraftIter,
            EventKind::VerifyAccept,
            EventKind::Preempt,
            EventKind::SwapOut,
            EventKind::SwapIn,
            EventKind::MigrateOut,
            EventKind::MigrateIn,
            EventKind::Escalate,
            EventKind::HotSwapApplied,
            EventKind::Finished,
        ];
        let mut names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), kinds.len(), "kind names must be unique");
        assert!(EventKind::Finished.is_terminal());
        assert!(!EventKind::Admitted.is_terminal());
    }

    #[test]
    fn plan_events_are_a_pure_function_of_the_plan() {
        let plan = IterationPlan {
            admitted: vec![1],
            prefill: vec![ChunkTask { id: 1, start: 32, len: 16, last: true }],
            decode: vec![0],
            preempted: vec![2],
            swapped_out: vec![(3, 4)],
            swapped_in: vec![(4, 2)],
            migrated_out: vec![(5, 3)],
            migrated_in: vec![(6, 2)],
            forced_expansions: 0,
            spec: vec![],
        };
        let rec_a = TraceRecorder::new(1, 64);
        let rec_b = TraceRecorder::new(1, 64);
        emit_plan_events(&rec_a, 0, 1.0, 0, &plan, |id| id as u64 + 100);
        emit_plan_events(&rec_b, 0, 99.0, 0, &plan, |id| id as u64 + 100);
        let a = rec_a.snapshot();
        let b = rec_b.snapshot();
        assert_eq!(a.len(), 7, "one event per plan entry (admitted itself is not an event)");
        let sig_a: Vec<_> = a.iter().map(|e| (e.req, e.signature())).collect();
        let sig_b: Vec<_> = b.iter().map(|e| (e.req, e.signature())).collect();
        assert_eq!(sig_a, sig_b, "signatures ignore timestamps");
        // The full-prompt chunk records tokens, start, and last.
        let chunk = a.iter().find(|e| e.kind == EventKind::PrefillChunk).unwrap();
        assert_eq!((chunk.a, chunk.b, chunk.c), (16, 32, 1));
        assert_eq!(chunk.req, 101);
        // Decode records the tick's batch size (prefill + decode).
        let dec = a.iter().find(|e| e.kind == EventKind::DecodeIter).unwrap();
        assert_eq!(dec.a, 2);
        // Migration events lead (out) and trail the swap block (in),
        // each carrying its private page count.
        assert_eq!(a[0].kind, EventKind::MigrateOut);
        assert_eq!((a[0].req, a[0].a), (105, 3));
        let min = a.iter().find(|e| e.kind == EventKind::MigrateIn).unwrap();
        assert_eq!((min.req, min.a), (106, 2));
    }

    #[test]
    fn spec_events_extend_the_vocabulary_without_touching_legacy_signatures() {
        use crate::engine::scheduler::SpecTask;
        // A plan with a speculative task emits draft_iter after the
        // plain decoders; the batch counts the speculating sequence.
        let plan = IterationPlan {
            decode: vec![0],
            spec: vec![SpecTask { id: 1, k: 4 }],
            ..IterationPlan::default()
        };
        let rec = TraceRecorder::new(1, 64);
        emit_plan_events(&rec, 0, 1.0, 0, &plan, |id| id as u64);
        let evs = rec.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].signature(), (EventKind::DecodeIter, 2, 0, 0));
        assert_eq!(evs[1].signature(), (EventKind::DraftIter, 4, 2, 0));

        // Settled results: verify_accept + a decode_iter carrying the
        // produced-token count; a degraded task (drafted == 0) emits
        // the legacy single-token decode_iter shape (b = 0).
        let rec = TraceRecorder::new(1, 64);
        let results = [
            SpecResult { id: 1, drafted: 4, accepted: 3, emitted: 4 },
            SpecResult { id: 2, drafted: 0, accepted: 0, emitted: 1 },
        ];
        emit_spec_events(&rec, 0, 2.0, 0, 2, &results, |id| id as u64);
        let evs = rec.snapshot();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].signature(), (EventKind::VerifyAccept, 3, 1, 0));
        assert_eq!(evs[1].signature(), (EventKind::DecodeIter, 2, 4, 0));
        assert_eq!(
            evs[2].signature(),
            (EventKind::DecodeIter, 2, 0, 0),
            "a degraded task is indistinguishable from a legacy decode"
        );
    }
}
