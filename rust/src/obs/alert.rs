//! SLO alerting: structured alerts with fire/clear/re-arm hysteresis.
//!
//! The profile aggregator ([`super::profile`]) reduces the event
//! stream to per-tier health signals (SLO attainment, multi-window
//! burn rate, queue-depth slope); this module turns those signals into
//! **edge-triggered** [`Alert`]s. Every alert condition is evaluated
//! with hysteresis: it fires once when the condition first holds,
//! stays latched (no re-fire storm) while it keeps holding, clears
//! when the signal drops below `clear_ratio` of its threshold, and
//! only then re-arms.
//!
//! Burn rate follows the SRE multi-window convention: with an
//! attainment target `T`, `burn = (1 - attainment) / (1 - T)` — burn 1
//! consumes the error budget exactly at the sustainable rate; the
//! alert requires **both** a short and a long window above threshold,
//! so a brief spike (short only) or stale history (long only) cannot
//! fire on its own.
//!
//! [`SloBurnMonitor`] is the standalone completion-fed variant the
//! adapt controller uses as its SLO-drift trigger: it owns its own
//! rolling windows and returns an [`Alert`] only on the rising edge.
//! After a corrective action (hot-swap) the controller resets the
//! windows but keeps the latch — one corrective action per burn
//! episode, re-arming only once attainment actually recovers.

use std::collections::BTreeMap;
use std::collections::VecDeque;

/// `tier` value for alerts not tied to a tier (e.g. recorder drops).
pub const TIER_NONE: u32 = u32::MAX;

/// The alert vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AlertKind {
    /// Multi-window SLO burn rate above threshold on a tier.
    SloBurnRate,
    /// Sustained queue-depth growth on a tier.
    QueueGrowth,
    /// The trace recorder dropped events (rings overflowed): spans are
    /// silently incomplete.
    TraceDrops,
}

impl AlertKind {
    /// Stable wire/export name.
    pub fn name(&self) -> &'static str {
        match self {
            AlertKind::SloBurnRate => "slo_burn_rate",
            AlertKind::QueueGrowth => "queue_growth",
            AlertKind::TraceDrops => "trace_drops",
        }
    }

    fn code(&self) -> u8 {
        match self {
            AlertKind::SloBurnRate => 0,
            AlertKind::QueueGrowth => 1,
            AlertKind::TraceDrops => 2,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Critical,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Critical => "critical",
        }
    }
}

/// One structured alert event (edge-triggered: emitted once per
/// episode).
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    pub kind: AlertKind,
    /// Tier the alert is about ([`TIER_NONE`] for system-wide alerts).
    pub tier: u32,
    pub severity: Severity,
    /// Human-readable signal values at fire time.
    pub evidence: String,
}

impl std::fmt::Display for Alert {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.tier == TIER_NONE {
            write!(f, "[{}] {}: {}", self.severity.name(), self.kind.name(), self.evidence)
        } else {
            write!(
                f,
                "[{}] {} tier {}: {}",
                self.severity.name(),
                self.kind.name(),
                self.tier,
                self.evidence
            )
        }
    }
}

/// Thresholds for the evaluator.
#[derive(Debug, Clone)]
pub struct AlertPolicy {
    /// Attainment target the burn rate is computed against (e.g. 0.95
    /// = 95% of requests inside the SLO).
    pub target: f64,
    /// Burn-rate level (both windows) that fires `SloBurnRate`; 1.0 =
    /// consuming the error budget exactly at the sustainable rate.
    pub burn_threshold: f64,
    /// A condition clears (re-arms) once its signal drops below
    /// `clear_ratio * threshold`.
    pub clear_ratio: f64,
    /// Queue-depth slope (requests/s, short window) firing
    /// `QueueGrowth` ...
    pub queue_slope_threshold: f64,
    /// ... but only above this standing depth (an empty queue growing
    /// by one is not an incident).
    pub queue_min_depth: f64,
    /// Minimum short-window completions before burn is trusted.
    pub min_samples: usize,
}

impl Default for AlertPolicy {
    fn default() -> Self {
        AlertPolicy {
            target: 0.95,
            burn_threshold: 1.0,
            clear_ratio: 0.5,
            queue_slope_threshold: 0.5,
            queue_min_depth: 4.0,
            min_samples: 10,
        }
    }
}

/// Per-tier health signals the evaluator consumes (produced by the
/// profile aggregator's rolling windows).
#[derive(Debug, Clone, Copy)]
pub struct TierSignals {
    pub tier: u32,
    pub attainment_short: f64,
    pub attainment_long: f64,
    pub burn_short: f64,
    pub burn_long: f64,
    /// Completions inside the short window (sample-size guard).
    pub samples_short: usize,
    pub queue_depth: f64,
    pub queue_slope_per_s: f64,
}

/// Edge-triggered alert evaluator with per-(kind, tier) hysteresis
/// state. Call sites re-evaluate the same evaluator on every refresh;
/// alerts come out only on rising edges.
#[derive(Debug)]
pub struct AlertEvaluator {
    pub policy: AlertPolicy,
    firing: BTreeMap<(u8, u32), bool>,
}

impl AlertEvaluator {
    pub fn new(policy: AlertPolicy) -> AlertEvaluator {
        AlertEvaluator { policy, firing: BTreeMap::new() }
    }

    /// Whether a given condition is currently latched.
    pub fn is_firing(&self, kind: AlertKind, tier: u32) -> bool {
        *self.firing.get(&(kind.code(), tier)).unwrap_or(&false)
    }

    /// Hysteresis step: returns true exactly on the rising edge.
    fn edge(&mut self, kind: AlertKind, tier: u32, on: bool, clear: bool) -> bool {
        let state = self.firing.entry((kind.code(), tier)).or_insert(false);
        if *state {
            if clear {
                *state = false;
            }
            false
        } else if on {
            *state = true;
            true
        } else {
            false
        }
    }

    /// Evaluate one tier's signals; returns newly-fired alerts.
    pub fn evaluate_tier(&mut self, s: &TierSignals) -> Vec<Alert> {
        let mut out = Vec::new();
        let p = &self.policy;
        let burn_on = s.samples_short >= p.min_samples
            && s.burn_short > p.burn_threshold
            && s.burn_long > p.burn_threshold;
        let burn_clear = s.burn_short < p.burn_threshold * p.clear_ratio;
        let (thr, clr) = (p.burn_threshold, p.clear_ratio);
        if self.edge(AlertKind::SloBurnRate, s.tier, burn_on, burn_clear) {
            let severity = if s.burn_short > 2.0 * thr {
                Severity::Critical
            } else {
                Severity::Warning
            };
            out.push(Alert {
                kind: AlertKind::SloBurnRate,
                tier: s.tier,
                severity,
                evidence: format!(
                    "burn short {:.2} / long {:.2} > {:.2} (attainment short {:.1}% long {:.1}%, {} samples)",
                    s.burn_short,
                    s.burn_long,
                    thr,
                    s.attainment_short * 100.0,
                    s.attainment_long * 100.0,
                    s.samples_short
                ),
            });
        }
        let q_on = s.queue_slope_per_s > self.policy.queue_slope_threshold
            && s.queue_depth >= self.policy.queue_min_depth;
        let q_clear = s.queue_slope_per_s < self.policy.queue_slope_threshold * clr;
        if self.edge(AlertKind::QueueGrowth, s.tier, q_on, q_clear) {
            out.push(Alert {
                kind: AlertKind::QueueGrowth,
                tier: s.tier,
                severity: Severity::Warning,
                evidence: format!(
                    "queue depth {:.0} growing {:+.2} req/s over the short window",
                    s.queue_depth, s.queue_slope_per_s
                ),
            });
        }
        out
    }

    /// Evaluate recorder health: any dropped event fires once per
    /// monotone increase episode (clears only if the count stops
    /// growing is not knowable from a total — so this latches until
    /// the evaluator is rebuilt; dropped spans never become complete).
    pub fn evaluate_drops(&mut self, dropped_events: u64) -> Option<Alert> {
        let on = dropped_events > 0;
        if self.edge(AlertKind::TraceDrops, TIER_NONE, on, false) {
            return Some(Alert {
                kind: AlertKind::TraceDrops,
                tier: TIER_NONE,
                severity: Severity::Warning,
                evidence: format!(
                    "{dropped_events} events lost to ring overflow — spans are incomplete"
                ),
            });
        }
        None
    }
}

/// Completion-fed SLO burn-rate monitor: the adapt controller's
/// SLO-drift trigger. Windows are time-based over the caller's clock
/// (wall seconds for a live server, simulated seconds in tests).
#[derive(Debug, Clone)]
pub struct SloBurnConfig {
    /// End-to-end latency SLO (same time base as observed latencies).
    pub slo_s: f64,
    /// Attainment target (fraction of requests inside the SLO).
    pub target: f64,
    /// Short ("fast burn") window, seconds.
    pub short_window_s: f64,
    /// Long ("sustained burn") window, seconds.
    pub long_window_s: f64,
    /// Burn level both windows must exceed to fire.
    pub burn_threshold: f64,
    /// Minimum completions in the short window before burn is trusted.
    pub min_samples: usize,
    /// Re-arm once short-window burn drops below `clear_ratio *
    /// burn_threshold`.
    pub clear_ratio: f64,
}

impl Default for SloBurnConfig {
    fn default() -> Self {
        SloBurnConfig {
            slo_s: 20.0,
            target: 0.9,
            short_window_s: 30.0,
            long_window_s: 240.0,
            burn_threshold: 1.5,
            min_samples: 20,
            clear_ratio: 0.5,
        }
    }
}

/// Rolling completion window + hysteresis latch. See module docs.
#[derive(Debug)]
pub struct SloBurnMonitor {
    pub config: SloBurnConfig,
    /// (completion time, within-SLO) samples inside the long window.
    window: VecDeque<(f64, bool)>,
    firing: bool,
}

/// `(1 - attainment) / (1 - target)`, clamped to a finite value for
/// targets at/above 1.
fn burn_rate(ok: usize, total: usize, target: f64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let attainment = ok as f64 / total as f64;
    let budget = (1.0 - target).max(1e-6);
    (1.0 - attainment) / budget
}

impl SloBurnMonitor {
    pub fn new(config: SloBurnConfig) -> SloBurnMonitor {
        SloBurnMonitor { config, window: VecDeque::new(), firing: false }
    }

    fn counts_since(&self, cutoff: f64) -> (usize, usize) {
        let mut ok = 0;
        let mut total = 0;
        for &(t, within) in self.window.iter().rev() {
            if t < cutoff {
                break;
            }
            total += 1;
            if within {
                ok += 1;
            }
        }
        (ok, total)
    }

    /// Short-window burn rate as of the latest observation.
    pub fn burn_short(&self) -> f64 {
        let now = self.window.back().map(|&(t, _)| t).unwrap_or(0.0);
        let (ok, total) = self.counts_since(now - self.config.short_window_s);
        burn_rate(ok, total, self.config.target)
    }

    /// Whether the latch is set (an episode is in progress).
    pub fn is_firing(&self) -> bool {
        self.firing
    }

    /// Record one completion. Returns an [`Alert`] exactly on the
    /// rising edge of the multi-window burn condition.
    pub fn observe(&mut self, now_s: f64, e2e_s: f64) -> Option<Alert> {
        let within = e2e_s <= self.config.slo_s;
        self.window.push_back((now_s, within));
        while let Some(&(t, _)) = self.window.front() {
            if t < now_s - self.config.long_window_s {
                self.window.pop_front();
            } else {
                break;
            }
        }
        let (ok_s, n_s) = self.counts_since(now_s - self.config.short_window_s);
        let (ok_l, n_l) = self.counts_since(now_s - self.config.long_window_s);
        let burn_s = burn_rate(ok_s, n_s, self.config.target);
        let burn_l = burn_rate(ok_l, n_l, self.config.target);
        if self.firing {
            if n_s >= self.config.min_samples
                && burn_s < self.config.burn_threshold * self.config.clear_ratio
            {
                self.firing = false;
            }
            return None;
        }
        let on = n_s >= self.config.min_samples
            && burn_s > self.config.burn_threshold
            && burn_l > self.config.burn_threshold;
        if !on {
            return None;
        }
        self.firing = true;
        let severity = if burn_s > 2.0 * self.config.burn_threshold {
            Severity::Critical
        } else {
            Severity::Warning
        };
        Some(Alert {
            kind: AlertKind::SloBurnRate,
            tier: TIER_NONE,
            severity,
            evidence: format!(
                "e2e > {:.2}s SLO: burn short {:.2} / long {:.2} > {:.2} ({} samples)",
                self.config.slo_s, burn_s, burn_l, self.config.burn_threshold, n_s
            ),
        })
    }

    /// Drop the window after a corrective action (hot-swap) so stale
    /// pre-swap latencies cannot bias post-swap burn. The latch is
    /// kept: one corrective action per episode — re-arming requires
    /// attainment to actually recover ([`SloBurnMonitor::observe`]
    /// clears the latch once short-window burn falls below the clear
    /// level).
    pub fn reset_after_swap(&mut self) {
        self.window.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SloBurnConfig {
        SloBurnConfig {
            slo_s: 1.0,
            target: 0.9,
            short_window_s: 10.0,
            long_window_s: 40.0,
            burn_threshold: 1.5,
            min_samples: 5,
            clear_ratio: 0.5,
        }
    }

    #[test]
    fn burn_breach_fires_once_clears_and_rearms() {
        let mut m = SloBurnMonitor::new(cfg());
        // Breaching completions: every request misses the 1s SLO.
        let mut fired = 0;
        for i in 0..20 {
            if m.observe(i as f64 * 0.1, 5.0).is_some() {
                fired += 1;
            }
        }
        assert_eq!(fired, 1, "breach must fire exactly once, not storm");
        assert!(m.is_firing());
        // Recovery: a run of within-SLO completions clears the latch...
        for i in 0..60 {
            assert!(m.observe(2.0 + i as f64 * 0.2, 0.2).is_none());
        }
        assert!(!m.is_firing(), "sustained recovery must re-arm");
        // ...and a fresh breach (re-filling both windows) re-fires.
        let mut refired = 0;
        for i in 0..40 {
            if m.observe(20.0 + i as f64 * 0.2, 5.0).is_some() {
                refired += 1;
            }
        }
        assert_eq!(refired, 1, "re-armed monitor fires again exactly once");
    }

    #[test]
    fn short_spike_alone_does_not_fire() {
        // min_samples guards the short window; a couple of slow
        // requests inside an otherwise-healthy long window stay quiet.
        let mut m = SloBurnMonitor::new(cfg());
        for i in 0..50 {
            assert!(m.observe(i as f64 * 0.5, 0.2).is_none());
        }
        assert!(m.observe(25.1, 5.0).is_none());
        assert!(m.observe(25.2, 5.0).is_none());
        assert!(!m.is_firing());
    }

    #[test]
    fn reset_after_swap_keeps_latch_until_recovery() {
        let mut m = SloBurnMonitor::new(cfg());
        for i in 0..20 {
            let _ = m.observe(i as f64 * 0.1, 5.0);
        }
        assert!(m.is_firing());
        m.reset_after_swap();
        // Still breaching after the swap: the latch holds, no re-fire.
        for i in 0..20 {
            assert!(m.observe(3.0 + i as f64 * 0.1, 5.0).is_none());
        }
        assert!(m.is_firing(), "latch must survive a window reset");
    }

    #[test]
    fn evaluator_hysteresis_per_kind_and_tier() {
        let mut ev = AlertEvaluator::new(AlertPolicy::default());
        let breach = TierSignals {
            tier: 1,
            attainment_short: 0.5,
            attainment_long: 0.6,
            burn_short: 10.0,
            burn_long: 8.0,
            samples_short: 50,
            queue_depth: 0.0,
            queue_slope_per_s: 0.0,
        };
        let first = ev.evaluate_tier(&breach);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].kind, AlertKind::SloBurnRate);
        assert_eq!(first[0].severity, Severity::Critical);
        assert_eq!(first[0].tier, 1);
        // Latched: same signals emit nothing.
        assert!(ev.evaluate_tier(&breach).is_empty());
        assert!(ev.is_firing(AlertKind::SloBurnRate, 1));
        // Clear below clear_ratio * threshold, then re-fire.
        let healthy = TierSignals { burn_short: 0.1, burn_long: 0.1, ..breach };
        assert!(ev.evaluate_tier(&healthy).is_empty());
        assert!(!ev.is_firing(AlertKind::SloBurnRate, 1));
        assert_eq!(ev.evaluate_tier(&breach).len(), 1, "cleared condition re-arms");
        // Drops alert fires once and latches.
        assert!(ev.evaluate_drops(0).is_none());
        assert!(ev.evaluate_drops(3).is_some());
        assert!(ev.evaluate_drops(5).is_none());
    }

    #[test]
    fn queue_growth_requires_depth_and_slope() {
        let mut ev = AlertEvaluator::new(AlertPolicy::default());
        let sig = TierSignals {
            tier: 0,
            attainment_short: 1.0,
            attainment_long: 1.0,
            burn_short: 0.0,
            burn_long: 0.0,
            samples_short: 50,
            queue_depth: 2.0, // below min_depth
            queue_slope_per_s: 3.0,
        };
        assert!(ev.evaluate_tier(&sig).is_empty(), "shallow queue must not fire");
        let deep = TierSignals { queue_depth: 30.0, ..sig };
        let alerts = ev.evaluate_tier(&deep);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, AlertKind::QueueGrowth);
    }
}
