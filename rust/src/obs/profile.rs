//! Latency attribution: fold the event stream into per-request phase
//! waterfalls and per-tier rolling health windows.
//!
//! ## Phase-attribution model
//!
//! A request's trace is its seq-ordered event sequence; the time
//! between consecutive events (a *gap*) is attributed to exactly one
//! [`Phase`] from the pair of event kinds bounding it, so the phases
//! form a complete partition of the span `[first event, finished]` —
//! waterfall sums are exact by construction, across escalation chains
//! included. The rules (first match wins):
//!
//! | gap bounded by | phase |
//! |----------------|-------|
//! | `* → queue_enter/queue_exit` | queue (escalation-transit after an `escalate` until compute restarts) |
//! | `prefill_chunk → *` | prefill (plan events are stamped at iteration start; the chunk executes *after* its event) |
//! | `decode_iter → *` | decode |
//! | `preempt → *` | preempt-stall |
//! | `swap_out/swap_in → *` | swap-stall |
//! | `migrate_out/migrate_in → *` | migration-transit (prefill→decode handoff: interconnect transfer + decode-side admission wait) |
//! | `admitted/queue_enter/queue_exit → prefill_chunk/decode_iter/swap_in` | queue (engine admission wait) |
//! | `admitted/queue_enter/queue_exit → route_decision/finished` | decode (lockstep/wire path: one opaque generate per tier) |
//! | `escalate → *` | escalation-transit |
//! | `route_decision → escalate` | escalation-transit |
//! | anything else | other |
//!
//! Traces without admission events (the DES, a standalone engine)
//! start at the first engine event; the pre-trace wait `fb - span`
//! (the `finished` event's measured e2e minus the event span) is
//! attributed to queue as the **lead residual**, reported separately —
//! so DES what-if attribution and live attribution share one schema.
//!
//! The **structural signature** (run-length-encoded phase visit
//! sequence) depends only on event kinds, never timestamps — a DES run
//! and its live-engine twin produce identical signatures for identical
//! plans, which is what `cascadia profile` pins on the diff-harness
//! workload.
//!
//! ## Rolling windows and alerts
//!
//! Per tier, the aggregator keeps rolling windows of completed-request
//! phase vectors (short/long, for SLO attainment and SRE-style
//! multi-window burn rate), live queue depth with a short-window
//! slope, and a busy-time integral (occupancy). [`AlertEvaluator`]
//! turns those signals into edge-triggered [`Alert`]s; the evaluator
//! lives inside the aggregator so hysteresis survives repeated
//! [`ProfileAggregator::report`] calls (the `cascadia top` refresh
//! loop).

use std::collections::{BTreeMap, VecDeque};

use super::alert::{Alert, AlertEvaluator, AlertPolicy, TierSignals};
use super::{Event, EventKind, ACTION_ESCALATE};

/// Number of attribution phases.
pub const N_PHASES: usize = 8;

/// The waterfall phases. Order is the rendering order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Queue,
    Prefill,
    Decode,
    PreemptStall,
    SwapStall,
    MigrationTransit,
    EscalationTransit,
    Other,
}

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::Queue,
        Phase::Prefill,
        Phase::Decode,
        Phase::PreemptStall,
        Phase::SwapStall,
        Phase::MigrationTransit,
        Phase::EscalationTransit,
        Phase::Other,
    ];

    /// Stable wire/export name.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::PreemptStall => "preempt_stall",
            Phase::SwapStall => "swap_stall",
            Phase::MigrationTransit => "migration_transit",
            Phase::EscalationTransit => "escalation_transit",
            Phase::Other => "other",
        }
    }

    fn idx(&self) -> usize {
        *self as usize
    }
}

/// Attribute the gap between two consecutive events of one request.
/// `in_transit` is true between an `escalate` and the next compute
/// event on the target tier (re-queue + re-admission delay after an
/// escalation counts as escalation-transit, not plain queueing).
fn gap_phase(prev: EventKind, next: EventKind, in_transit: bool) -> Phase {
    use EventKind as K;
    let queueish = if in_transit { Phase::EscalationTransit } else { Phase::Queue };
    if matches!(next, K::QueueEnter | K::QueueExit) {
        return queueish;
    }
    match prev {
        K::PrefillChunk => Phase::Prefill,
        K::DecodeIter => Phase::Decode,
        K::Preempt => Phase::PreemptStall,
        K::SwapOut | K::SwapIn => Phase::SwapStall,
        // A handoff leaves the prefill engine at `migrate_out` and is
        // resident again at `migrate_in` (which decodes the same tick)
        // — everything between is interconnect transit plus
        // decode-side admission wait.
        K::MigrateOut | K::MigrateIn => Phase::MigrationTransit,
        K::Escalate => Phase::EscalationTransit,
        K::Admitted | K::QueueEnter | K::QueueExit => match next {
            K::RouteDecision | K::Finished => Phase::Decode,
            _ => queueish,
        },
        K::RouteDecision => {
            if next == K::Escalate {
                Phase::EscalationTransit
            } else {
                Phase::Other
            }
        }
        _ => Phase::Other,
    }
}

/// One completed request's attribution.
#[derive(Debug, Clone)]
pub struct Waterfall {
    pub req: u64,
    /// Seconds per phase (indexed by `Phase as usize`); includes the
    /// lead residual in the queue bucket, so the phases sum to
    /// `max(span_s, e2e_s)` up to clock skew.
    pub phases: [f64; N_PHASES],
    /// Event span: `t(finished) - t(first event)`.
    pub span_s: f64,
    /// Measured e2e latency (the `finished` event's `fb`).
    pub e2e_s: f64,
    /// Measured TTFT (the `finished` event's `fa`).
    pub ttft_s: f64,
    /// `max(0, e2e_s - span_s)`: pre-trace wait, attributed to queue
    /// (nonzero for DES/standalone traces that lack admission events).
    pub lead_residual_s: f64,
    /// Whether an `admitted` event opened the span (live server trace).
    pub admitted: bool,
    pub entry_tier: u32,
    /// Tier that emitted `finished`.
    pub final_tier: u32,
    pub escalations: u32,
    /// Run-length-encoded phase visit sequence — structural, depends
    /// only on event kinds (the DES↔live identity surface).
    pub signature: Vec<(Phase, u32)>,
}

impl Waterfall {
    /// Sum of all attributed phase time (== span + lead residual).
    pub fn total_s(&self) -> f64 {
        self.phases.iter().sum()
    }
}

/// Aggregator knobs.
#[derive(Debug, Clone)]
pub struct ProfileConfig {
    /// E2e SLO for attainment/burn (None disables SLO evaluation).
    pub slo_s: Option<f64>,
    /// Attainment target for burn rates.
    pub target: f64,
    /// Short rolling window, seconds of trace time.
    pub short_window_s: f64,
    /// Long rolling window, seconds of trace time.
    pub long_window_s: f64,
    pub alert_policy: AlertPolicy,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig {
            slo_s: None,
            target: 0.95,
            short_window_s: 60.0,
            long_window_s: 600.0,
            alert_policy: AlertPolicy::default(),
        }
    }
}

/// In-flight per-request fold state.
struct ReqState {
    first_t: f64,
    /// False until the opening event has been recorded (the first
    /// event opens the span; only the second onward closes a gap).
    primed: bool,
    prev_t: f64,
    prev_kind: EventKind,
    in_transit: bool,
    admitted: bool,
    entry_tier: u32,
    escalations: u32,
    /// Tier currently holding the request in its engine (between
    /// queue-exit/first compute and its route decision) — for the
    /// occupancy integral.
    resident_tier: Option<u32>,
    phases: [f64; N_PHASES],
    /// Phase time spent per tier (gap attributed to the tier of the
    /// event that closes it).
    tier_phases: BTreeMap<u32, [f64; N_PHASES]>,
    sig: Vec<(Phase, u32)>,
}

/// One completed request's contribution to a tier window.
struct TierSample {
    t: f64,
    phases: [f64; N_PHASES],
    e2e_s: f64,
    within_slo: bool,
    finished_here: bool,
}

/// Rolling per-tier state.
#[derive(Default)]
struct TierState {
    depth: i64,
    depth_samples: VecDeque<(f64, f64)>,
    active: i64,
    busy_s: f64,
    last_active_t: f64,
    recent: VecDeque<TierSample>,
    completed: u64,
    escalated_out: u64,
}

impl TierState {
    fn set_active(&mut self, t: f64, delta: i64) {
        if self.active > 0 && t > self.last_active_t {
            self.busy_s += t - self.last_active_t;
        }
        self.last_active_t = self.last_active_t.max(t);
        self.active = (self.active + delta).max(0);
    }
}

/// Streaming fold of the event stream. Feed [`Event`]s in seq order
/// (a [`TraceRecorder::snapshot`](super::TraceRecorder::snapshot) is
/// already sorted); read back waterfalls and a [`ProfileReport`].
pub struct ProfileAggregator {
    cfg: ProfileConfig,
    pending: BTreeMap<u64, ReqState>,
    done: Vec<Waterfall>,
    tiers: BTreeMap<u32, TierState>,
    evaluator: AlertEvaluator,
    alerts: Vec<Alert>,
    first_t: Option<f64>,
    now: f64,
    events: u64,
    hot_swaps: u64,
}

fn push_sig(sig: &mut Vec<(Phase, u32)>, ph: Phase) {
    match sig.last_mut() {
        Some((last, n)) if *last == ph => *n += 1,
        _ => sig.push((ph, 1)),
    }
}

/// p-quantile of an unsorted sample (nearest-rank); 0 for empty.
fn percentile(values: &mut [f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p * values.len() as f64).ceil() as usize).clamp(1, values.len());
    values[rank - 1]
}

impl ProfileAggregator {
    pub fn new(cfg: ProfileConfig) -> ProfileAggregator {
        let evaluator = AlertEvaluator::new(cfg.alert_policy.clone());
        ProfileAggregator {
            cfg,
            pending: BTreeMap::new(),
            done: Vec::new(),
            tiers: BTreeMap::new(),
            evaluator,
            alerts: Vec::new(),
            first_t: None,
            now: 0.0,
            events: 0,
            hot_swaps: 0,
        }
    }

    /// Fold a full trace (events must be in seq order, as
    /// `snapshot()` returns them).
    pub fn fold(cfg: ProfileConfig, events: &[Event]) -> ProfileAggregator {
        let mut agg = ProfileAggregator::new(cfg);
        for ev in events {
            agg.observe(ev);
        }
        agg
    }

    /// Completed-request waterfalls so far, completion order.
    pub fn waterfalls(&self) -> &[Waterfall] {
        &self.done
    }

    /// Requests with an open span (no `finished` seen yet).
    pub fn open_requests(&self) -> usize {
        self.pending.len()
    }

    /// Alerts fired so far (edge-triggered, in fire order).
    pub fn alerts(&self) -> &[Alert] {
        &self.alerts
    }

    fn tier(&mut self, tier: u32) -> &mut TierState {
        self.tiers.entry(tier).or_default()
    }

    /// Feed one event. Events of one request must arrive in seq order;
    /// interleaving across requests is fine.
    pub fn observe(&mut self, ev: &Event) {
        self.events += 1;
        self.now = self.now.max(ev.t);
        if self.first_t.is_none() {
            self.first_t = Some(ev.t);
        }
        if ev.req == super::REQ_NONE {
            if ev.kind == EventKind::HotSwapApplied {
                self.hot_swaps += 1;
            }
            return;
        }

        // Tier-level bookkeeping: queue depth and engine residency.
        match ev.kind {
            EventKind::QueueEnter => {
                let t = self.tier(ev.tier);
                t.depth += 1;
                let d = t.depth as f64;
                t.depth_samples.push_back((ev.t, d));
            }
            EventKind::QueueExit => {
                let t = self.tier(ev.tier);
                t.depth = (t.depth - 1).max(0);
                let d = t.depth as f64;
                t.depth_samples.push_back((ev.t, d));
            }
            EventKind::Escalate => {
                self.tier(ev.tier).escalated_out += 1;
            }
            _ => {}
        }
        let short_w = self.cfg.short_window_s;
        if let Some(ts) = self.tiers.get_mut(&ev.tier) {
            while let Some(&(t, _)) = ts.depth_samples.front() {
                if t < ev.t - 2.0 * short_w {
                    ts.depth_samples.pop_front();
                } else {
                    break;
                }
            }
        }

        // Residency (occupancy integral): a request occupies a tier's
        // engine from queue-exit (or first compute event, for traces
        // without queue events) until its route decision / finish.
        let takes_residence = matches!(
            ev.kind,
            EventKind::QueueExit | EventKind::PrefillChunk | EventKind::DecodeIter
        );
        let leaves_residence = matches!(ev.kind, EventKind::RouteDecision | EventKind::Finished);
        let prev_residence = self.pending.get(&ev.req).and_then(|s| s.resident_tier);
        if takes_residence && prev_residence != Some(ev.tier) {
            if let Some(old) = prev_residence {
                self.tier(old).set_active(ev.t, -1);
            }
            self.tier(ev.tier).set_active(ev.t, 1);
        } else if leaves_residence && prev_residence.is_some() {
            let old = prev_residence.unwrap_or(ev.tier);
            self.tier(old).set_active(ev.t, -1);
        }

        // Per-request fold.
        let state = self.pending.entry(ev.req).or_insert_with(|| ReqState {
            first_t: ev.t,
            primed: false,
            prev_t: ev.t,
            prev_kind: ev.kind,
            in_transit: false,
            admitted: false,
            entry_tier: ev.tier,
            escalations: 0,
            resident_tier: None,
            phases: [0.0; N_PHASES],
            tier_phases: BTreeMap::new(),
            sig: Vec::new(),
        });
        if state.primed {
            let gap = (ev.t - state.prev_t).max(0.0);
            let ph = gap_phase(state.prev_kind, ev.kind, state.in_transit);
            state.phases[ph.idx()] += gap;
            let tp = state.tier_phases.entry(ev.tier).or_insert([0.0; N_PHASES]);
            tp[ph.idx()] += gap;
            push_sig(&mut state.sig, ph);
        }
        state.primed = true;
        state.prev_t = ev.t;
        state.prev_kind = ev.kind;
        match ev.kind {
            EventKind::Admitted => {
                state.admitted = true;
                state.entry_tier = ev.a as u32;
            }
            EventKind::Escalate => {
                state.escalations += 1;
                state.in_transit = true;
            }
            EventKind::PrefillChunk | EventKind::DecodeIter | EventKind::RouteDecision => {
                state.in_transit = false;
            }
            _ => {}
        }
        if takes_residence {
            state.resident_tier = Some(ev.tier);
        } else if leaves_residence {
            state.resident_tier = None;
        }

        if ev.kind == EventKind::Finished {
            self.finish(ev);
        }
    }

    fn finish(&mut self, ev: &Event) {
        let Some(mut state) = self.pending.remove(&ev.req) else { return };
        let span = (ev.t - state.first_t).max(0.0);
        let lead = (ev.fb - span).max(0.0);
        state.phases[Phase::Queue.idx()] += lead;
        let within_slo = match self.cfg.slo_s {
            Some(slo) => ev.fb <= slo,
            None => true,
        };
        let long_w = self.cfg.long_window_s;
        for (tier, phases) in &state.tier_phases {
            let ts = self.tier(*tier);
            ts.recent.push_back(TierSample {
                t: ev.t,
                phases: *phases,
                e2e_s: ev.fb,
                within_slo,
                finished_here: *tier == ev.tier,
            });
            while let Some(front) = ts.recent.front() {
                if front.t < ev.t - long_w {
                    ts.recent.pop_front();
                } else {
                    break;
                }
            }
        }
        // A request served entirely pre-trace queue (no tier events) or
        // a wire trace without engine events still lands on the
        // finishing tier's window.
        if !state.tier_phases.contains_key(&ev.tier) {
            self.tier(ev.tier).recent.push_back(TierSample {
                t: ev.t,
                phases: [0.0; N_PHASES],
                e2e_s: ev.fb,
                within_slo,
                finished_here: true,
            });
        }
        self.tier(ev.tier).completed += 1;
        self.done.push(Waterfall {
            req: ev.req,
            phases: state.phases,
            span_s: span,
            e2e_s: ev.fb,
            ttft_s: ev.fa,
            lead_residual_s: lead,
            admitted: state.admitted,
            entry_tier: state.entry_tier,
            final_tier: ev.tier,
            escalations: state.escalations,
            signature: state.sig,
        });
    }

    fn tier_signals(&self, tier: u32, ts: &TierState) -> TierSignals {
        let now = self.now;
        let (mut ok_s, mut n_s, mut ok_l, mut n_l) = (0usize, 0usize, 0usize, 0usize);
        for s in ts.recent.iter().rev() {
            if !s.finished_here {
                continue;
            }
            if s.t >= now - self.cfg.long_window_s {
                n_l += 1;
                if s.within_slo {
                    ok_l += 1;
                }
            }
            if s.t >= now - self.cfg.short_window_s {
                n_s += 1;
                if s.within_slo {
                    ok_s += 1;
                }
            }
        }
        let budget = (1.0 - self.cfg.target).max(1e-6);
        let att = |ok: usize, n: usize| if n == 0 { 1.0 } else { ok as f64 / n as f64 };
        let (a_s, a_l) = (att(ok_s, n_s), att(ok_l, n_l));
        // Queue-depth slope: least squares over the short window.
        let cutoff = now - self.cfg.short_window_s;
        let pts: Vec<(f64, f64)> =
            ts.depth_samples.iter().filter(|(t, _)| *t >= cutoff).copied().collect();
        let slope = if pts.len() >= 2 {
            let n = pts.len() as f64;
            let mx = pts.iter().map(|(t, _)| t).sum::<f64>() / n;
            let my = pts.iter().map(|(_, d)| d).sum::<f64>() / n;
            let sxx: f64 = pts.iter().map(|(t, _)| (t - mx) * (t - mx)).sum();
            let sxy: f64 = pts.iter().map(|(t, d)| (t - mx) * (d - my)).sum();
            if sxx > 1e-12 {
                sxy / sxx
            } else {
                0.0
            }
        } else {
            0.0
        };
        TierSignals {
            tier,
            attainment_short: a_s,
            attainment_long: a_l,
            burn_short: (1.0 - a_s) / budget,
            burn_long: (1.0 - a_l) / budget,
            samples_short: n_s,
            queue_depth: ts.depth as f64,
            queue_slope_per_s: slope,
        }
    }

    /// Build the report as of the latest observed event, evaluating
    /// alerts with persistent hysteresis. `dropped_events` is the
    /// recorder's overflow count (0 when unknown).
    pub fn report(&mut self, dropped_events: u64) -> ProfileReport {
        let mut new_alerts: Vec<Alert> = Vec::new();
        if self.cfg.slo_s.is_some() {
            let tiers: Vec<u32> = self.tiers.keys().copied().collect();
            for tier in tiers {
                let sig = {
                    let ts = &self.tiers[&tier];
                    self.tier_signals(tier, ts)
                };
                new_alerts.extend(self.evaluator.evaluate_tier(&sig));
            }
        }
        if let Some(a) = self.evaluator.evaluate_drops(dropped_events) {
            new_alerts.push(a);
        }
        self.alerts.extend(new_alerts);

        let first_t = self.first_t.unwrap_or(0.0);
        let trace_span = (self.now - first_t).max(0.0);
        let mut e2e: Vec<f64> = self.done.iter().map(|w| w.e2e_s).collect();
        let mut ttft: Vec<f64> = self.done.iter().map(|w| w.ttft_s).collect();
        let e2e_mean = if e2e.is_empty() { 0.0 } else { e2e.iter().sum::<f64>() / e2e.len() as f64 };
        let mut phases = Vec::with_capacity(N_PHASES);
        for p in Phase::ALL {
            let mut v: Vec<f64> = self.done.iter().map(|w| w.phases[p.idx()]).collect();
            let total: f64 = v.iter().sum();
            let mean = if v.is_empty() { 0.0 } else { total / v.len() as f64 };
            phases.push(PhaseStat {
                phase: p,
                p50_s: percentile(&mut v, 0.50),
                p95_s: percentile(&mut v, 0.95),
                mean_s: mean,
                total_s: total,
            });
        }
        // Attribution error: phases must sum to the measured e2e. Only
        // spans opened by an `admitted` event are checked (for
        // DES/standalone traces the lead residual makes the sum exact
        // by construction, which would be a vacuous check).
        let mut errs: Vec<f64> = Vec::new();
        let mut err_fracs: Vec<f64> = Vec::new();
        for w in self.done.iter().filter(|w| w.admitted) {
            let err = (w.total_s() - w.e2e_s).abs();
            errs.push(err);
            err_fracs.push(err / w.e2e_s.max(1e-3));
        }
        let matched = errs.len();
        let tiers: Vec<TierReport> = self
            .tiers
            .iter()
            .map(|(tier, ts)| {
                let sig = self.tier_signals(*tier, ts);
                let busy = ts.busy_s
                    + if ts.active > 0 { (self.now - ts.last_active_t).max(0.0) } else { 0.0 };
                let mut tier_phases = Vec::with_capacity(N_PHASES);
                for p in Phase::ALL {
                    let mut v: Vec<f64> = ts.recent.iter().map(|s| s.phases[p.idx()]).collect();
                    let total: f64 = v.iter().sum();
                    let mean = if v.is_empty() { 0.0 } else { total / v.len() as f64 };
                    tier_phases.push(PhaseStat {
                        phase: p,
                        p50_s: percentile(&mut v, 0.50),
                        p95_s: percentile(&mut v, 0.95),
                        mean_s: mean,
                        total_s: total,
                    });
                }
                let mut w_e2e: Vec<f64> = ts
                    .recent
                    .iter()
                    .filter(|s| s.finished_here)
                    .map(|s| s.e2e_s)
                    .collect();
                TierReport {
                    tier: *tier,
                    completed: ts.completed,
                    escalated_out: ts.escalated_out,
                    queue_depth: ts.depth.max(0) as u64,
                    queue_slope_per_s: sig.queue_slope_per_s,
                    busy_frac: if trace_span > 0.0 { (busy / trace_span).min(1.0) } else { 0.0 },
                    window_p95_s: percentile(&mut w_e2e, 0.95),
                    attainment_short: sig.attainment_short,
                    attainment_long: sig.attainment_long,
                    burn_short: sig.burn_short,
                    burn_long: sig.burn_long,
                    phases: tier_phases,
                }
            })
            .collect();
        ProfileReport {
            requests: self.done.len(),
            open_requests: self.pending.len(),
            events: self.events,
            dropped_events,
            hot_swaps: self.hot_swaps,
            trace_span_s: trace_span,
            slo_s: self.cfg.slo_s,
            target: self.cfg.target,
            e2e_p50_s: percentile(&mut e2e, 0.50),
            e2e_p95_s: percentile(&mut e2e, 0.95),
            e2e_mean_s: e2e_mean,
            ttft_p50_s: percentile(&mut ttft, 0.50),
            ttft_p95_s: percentile(&mut ttft, 0.95),
            phases,
            attribution_matched: matched,
            attribution_p95_err_s: percentile(&mut errs, 0.95),
            attribution_p95_err_frac: percentile(&mut err_fracs, 0.95),
            tiers,
            alerts: self.alerts.clone(),
        }
    }
}

/// Quantiles of one phase across requests.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: Phase,
    pub p50_s: f64,
    pub p95_s: f64,
    pub mean_s: f64,
    pub total_s: f64,
}

/// Rolled-up per-tier health.
#[derive(Debug, Clone)]
pub struct TierReport {
    pub tier: u32,
    pub completed: u64,
    pub escalated_out: u64,
    pub queue_depth: u64,
    pub queue_slope_per_s: f64,
    /// Fraction of the trace span this tier had ≥1 resident request.
    pub busy_frac: f64,
    /// p95 e2e of requests finishing here inside the long window.
    pub window_p95_s: f64,
    pub attainment_short: f64,
    pub attainment_long: f64,
    pub burn_short: f64,
    pub burn_long: f64,
    pub phases: Vec<PhaseStat>,
}

/// The rendered aggregation — one schema for DES runs, live traces,
/// and the `/profile` endpoint.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub requests: usize,
    pub open_requests: usize,
    pub events: u64,
    pub dropped_events: u64,
    pub hot_swaps: u64,
    pub trace_span_s: f64,
    pub slo_s: Option<f64>,
    pub target: f64,
    pub e2e_p50_s: f64,
    pub e2e_p95_s: f64,
    pub e2e_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub phases: Vec<PhaseStat>,
    /// Requests whose waterfall was checked against measured e2e
    /// (spans opened by an `admitted` event).
    pub attribution_matched: usize,
    pub attribution_p95_err_s: f64,
    pub attribution_p95_err_frac: f64,
    pub tiers: Vec<TierReport>,
    pub alerts: Vec<Alert>,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn phases_json(phases: &[PhaseStat]) -> String {
    let items: Vec<String> = phases
        .iter()
        .map(|p| {
            format!(
                "{{\"phase\":\"{}\",\"p50_s\":{:.6},\"p95_s\":{:.6},\"mean_s\":{:.6},\"total_s\":{:.6}}}",
                p.phase.name(),
                p.p50_s,
                p.p95_s,
                p.mean_s,
                p.total_s
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

impl ProfileReport {
    /// The `/profile` endpoint schema (`cascadia.profile.v1`),
    /// documented in DESIGN.md.
    pub fn to_json(&self) -> String {
        let tiers: Vec<String> = self
            .tiers
            .iter()
            .map(|t| {
                format!(
                    "{{\"tier\":{},\"completed\":{},\"escalated_out\":{},\"queue_depth\":{},\
                     \"queue_slope_per_s\":{:.6},\"busy_frac\":{:.6},\"window_p95_s\":{:.6},\
                     \"attainment_short\":{:.6},\"attainment_long\":{:.6},\
                     \"burn_short\":{:.6},\"burn_long\":{:.6},\"phases\":{}}}",
                    t.tier,
                    t.completed,
                    t.escalated_out,
                    t.queue_depth,
                    t.queue_slope_per_s,
                    t.busy_frac,
                    t.window_p95_s,
                    t.attainment_short,
                    t.attainment_long,
                    t.burn_short,
                    t.burn_long,
                    phases_json(&t.phases)
                )
            })
            .collect();
        let alerts: Vec<String> = self
            .alerts
            .iter()
            .map(|a| {
                format!(
                    "{{\"kind\":\"{}\",\"tier\":{},\"severity\":\"{}\",\"evidence\":\"{}\"}}",
                    a.kind.name(),
                    if a.tier == super::alert::TIER_NONE { -1 } else { a.tier as i64 },
                    a.severity.name(),
                    json_escape(&a.evidence)
                )
            })
            .collect();
        format!(
            "{{\"schema\":\"cascadia.profile.v1\",\"requests\":{},\"open_requests\":{},\
             \"events\":{},\"dropped_events\":{},\"hot_swaps\":{},\"trace_span_s\":{:.6},\
             \"slo_s\":{},\"target\":{:.4},\
             \"e2e\":{{\"p50_s\":{:.6},\"p95_s\":{:.6},\"mean_s\":{:.6}}},\
             \"ttft\":{{\"p50_s\":{:.6},\"p95_s\":{:.6}}},\
             \"attribution\":{{\"matched\":{},\"p95_err_s\":{:.6},\"p95_err_frac\":{:.6}}},\
             \"phases\":{},\"tiers\":[{}],\"alerts\":[{}]}}",
            self.requests,
            self.open_requests,
            self.events,
            self.dropped_events,
            self.hot_swaps,
            self.trace_span_s,
            match self.slo_s {
                Some(s) => format!("{s:.4}"),
                None => "null".to_string(),
            },
            self.target,
            self.e2e_p50_s,
            self.e2e_p95_s,
            self.e2e_mean_s,
            self.ttft_p50_s,
            self.ttft_p95_s,
            self.attribution_matched,
            self.attribution_p95_err_s,
            self.attribution_p95_err_frac,
            phases_json(&self.phases),
            tiers.join(","),
            alerts.join(",")
        )
    }

    /// Terminal waterfall rendering (`cascadia profile`).
    pub fn render(&self) -> String {
        use crate::report::Table;
        let mut out = String::new();
        out.push_str(&format!(
            "{} requests ({} open), {} events ({} dropped), span {:.2}s, {} hot-swaps\n\
             e2e p50 {:.3}s p95 {:.3}s | ttft p50 {:.3}s p95 {:.3}s | attribution p95 err {:.2}% ({} matched)\n",
            self.requests,
            self.open_requests,
            self.events,
            self.dropped_events,
            self.trace_span_s,
            self.hot_swaps,
            self.e2e_p50_s,
            self.e2e_p95_s,
            self.ttft_p50_s,
            self.ttft_p95_s,
            self.attribution_p95_err_frac * 100.0,
            self.attribution_matched
        ));
        let mut t = Table::new(
            "latency attribution (per-request phase waterfall)",
            &["phase", "p50(s)", "p95(s)", "mean(s)", "share", "bar"],
        );
        let grand: f64 = self.phases.iter().map(|p| p.total_s).sum();
        for p in &self.phases {
            let share = if grand > 0.0 { p.total_s / grand } else { 0.0 };
            let bar = "#".repeat((share * 40.0).round() as usize);
            t.row(vec![
                p.phase.name().to_string(),
                format!("{:.4}", p.p50_s),
                format!("{:.4}", p.p95_s),
                format!("{:.4}", p.mean_s),
                format!("{:.1}%", share * 100.0),
                bar,
            ]);
        }
        out.push_str(&t.render());
        let mut tt = Table::new(
            "tier health (rolling windows)",
            &[
                "tier", "done", "esc", "depth", "slope/s", "busy", "p95(s)", "att(s/l)",
                "burn(s/l)",
            ],
        );
        for tr in &self.tiers {
            tt.row(vec![
                tr.tier.to_string(),
                tr.completed.to_string(),
                tr.escalated_out.to_string(),
                tr.queue_depth.to_string(),
                format!("{:+.2}", tr.queue_slope_per_s),
                format!("{:.0}%", tr.busy_frac * 100.0),
                format!("{:.3}", tr.window_p95_s),
                format!("{:.0}/{:.0}%", tr.attainment_short * 100.0, tr.attainment_long * 100.0),
                format!("{:.1}/{:.1}", tr.burn_short, tr.burn_long),
            ]);
        }
        out.push_str(&tt.render());
        if self.alerts.is_empty() {
            out.push_str("alerts: none\n");
        } else {
            for a in &self.alerts {
                out.push_str(&format!("alert: {a}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::alert::AlertKind;
    use super::super::REQ_NONE;
    use super::*;

    fn ev(seq: u64, t: f64, req: u64, tier: u32, kind: EventKind) -> Event {
        Event { seq, ..Event::at(t, req, tier, kind) }
    }

    /// The satellite-mandated chain: served on tier 0, escalated to
    /// tier 1, preempted once there — phases must sum exactly to the
    /// measured end-to-end latency.
    #[test]
    fn escalation_chain_waterfall_sums_to_e2e() {
        let mut seq = 0u64;
        let mut s = |t: f64, tier: u32, kind: EventKind| {
            seq += 1;
            ev(seq, t, 7, tier, kind)
        };
        let mut events = vec![
            Event { a: 0, ..s(0.0, 0, EventKind::Admitted) },
            s(0.0, 0, EventKind::QueueEnter),
            s(0.1, 0, EventKind::QueueExit),
            s(0.2, 0, EventKind::PrefillChunk),
            s(0.3, 0, EventKind::DecodeIter),
            s(0.4, 0, EventKind::DecodeIter),
            Event { a: ACTION_ESCALATE, b: 1, ..s(0.5, 0, EventKind::RouteDecision) },
            Event { a: 0, b: 1, ..s(0.5, 0, EventKind::Escalate) },
            s(0.5, 1, EventKind::QueueEnter),
            s(0.8, 1, EventKind::QueueExit),
            s(0.9, 1, EventKind::PrefillChunk),
            s(1.0, 1, EventKind::Preempt),
            s(1.3, 1, EventKind::PrefillChunk),
            s(1.4, 1, EventKind::DecodeIter),
            Event { a: 0, b: 1, ..s(1.5, 1, EventKind::RouteDecision) },
        ];
        events.push(Event { fa: 0.3, fb: 1.5, ..s(1.5, 1, EventKind::Finished) });
        let mut agg = ProfileAggregator::fold(ProfileConfig::default(), &events);
        assert_eq!(agg.waterfalls().len(), 1);
        let w = &agg.waterfalls()[0];
        assert!(w.admitted);
        assert_eq!(w.escalations, 1);
        assert_eq!((w.entry_tier, w.final_tier), (0, 1));
        let sum = w.total_s();
        assert!((sum - 1.5).abs() < 1e-9, "phases {:?} sum {} != e2e 1.5", w.phases, sum);
        // Exact per-phase expectations from the attribution table.
        let p = |ph: Phase| w.phases[ph.idx()];
        assert!((p(Phase::Queue) - 0.2).abs() < 1e-9, "queue {}", p(Phase::Queue));
        // prefill: 0.2→0.3 on tier 0, 0.9→1.0 and 1.3→1.4 on tier 1.
        assert!((p(Phase::Prefill) - 0.3).abs() < 1e-9, "prefill {}", p(Phase::Prefill));
        // decode: 0.3→0.4→0.5 on tier 0, 1.4→1.5 on tier 1.
        assert!((p(Phase::Decode) - 0.3).abs() < 1e-9, "decode {}", p(Phase::Decode));
        assert!((p(Phase::PreemptStall) - 0.3).abs() < 1e-9);
        // transit: route→escalate 0, escalate→queue_enter 0, re-queue
        // 0.5→0.8, queue_exit→prefill 0.8→0.9.
        assert!((p(Phase::EscalationTransit) - 0.4).abs() < 1e-9);
        assert!(p(Phase::SwapStall).abs() < 1e-12);
        // route_decision(accept)→finished lands in `other` with zero
        // width here.
        assert!(p(Phase::Other).abs() < 1e-12);
        let report = agg.report(0);
        assert_eq!(report.requests, 1);
        assert_eq!(report.attribution_matched, 1);
        assert!(report.attribution_p95_err_s < 1e-9);
    }

    #[test]
    fn des_style_trace_books_pre_span_wait_as_queue_residual() {
        // DES/standalone traces have no admission events: the span
        // opens at the first engine event, and `fb` (measured from
        // arrival) exceeds the span by the queue wait.
        let events = vec![
            ev(1, 10.0, 3, 0, EventKind::PrefillChunk),
            ev(2, 10.5, 3, 0, EventKind::DecodeIter),
            ev(3, 11.0, 3, 0, EventKind::DecodeIter),
            Event { fa: 2.5, fb: 3.0, ..ev(4, 11.0, 3, 0, EventKind::Finished) },
        ];
        let mut agg = ProfileAggregator::fold(ProfileConfig::default(), &events);
        let w = &agg.waterfalls()[0];
        assert!(!w.admitted);
        assert!((w.span_s - 1.0).abs() < 1e-9);
        assert!((w.lead_residual_s - 2.0).abs() < 1e-9, "fb 3.0 - span 1.0");
        assert!((w.phases[Phase::Queue.idx()] - 2.0).abs() < 1e-9);
        assert!((w.total_s() - 3.0).abs() < 1e-9, "waterfall sums to fb");
        // Unmatched traces are excluded from the attribution check.
        let report = agg.report(0);
        assert_eq!(report.attribution_matched, 0);
    }

    #[test]
    fn signature_is_structural_and_timestamp_free() {
        let mk = |scale: f64| {
            vec![
                ev(1, 0.0 * scale, 9, 0, EventKind::PrefillChunk),
                ev(2, 1.0 * scale, 9, 0, EventKind::PrefillChunk),
                ev(3, 2.0 * scale, 9, 0, EventKind::DecodeIter),
                Event { fa: 0.1, fb: 3.0 * scale, ..ev(4, 3.0 * scale, 9, 0, EventKind::Finished) },
            ]
        };
        let a = ProfileAggregator::fold(ProfileConfig::default(), &mk(1.0));
        let b = ProfileAggregator::fold(ProfileConfig::default(), &mk(250.0));
        assert_eq!(
            a.waterfalls()[0].signature,
            b.waterfalls()[0].signature,
            "signatures must ignore the clock"
        );
        assert_eq!(
            a.waterfalls()[0].signature,
            vec![(Phase::Prefill, 2), (Phase::Decode, 1)]
        );
    }

    #[test]
    fn swap_gaps_are_swap_stall() {
        let events = vec![
            ev(1, 0.0, 2, 0, EventKind::PrefillChunk),
            ev(2, 1.0, 2, 0, EventKind::DecodeIter),
            Event { a: 4, ..ev(3, 2.0, 2, 0, EventKind::SwapOut) },
            Event { a: 4, ..ev(4, 5.0, 2, 0, EventKind::SwapIn) },
            ev(5, 6.0, 2, 0, EventKind::DecodeIter),
            Event { fa: 1.0, fb: 7.0, ..ev(6, 7.0, 2, 0, EventKind::Finished) },
        ];
        let agg = ProfileAggregator::fold(ProfileConfig::default(), &events);
        let w = &agg.waterfalls()[0];
        // swap_out→swap_in (3s) + swap_in→decode (1s) are stall.
        assert!((w.phases[Phase::SwapStall.idx()] - 4.0).abs() < 1e-9);
        assert!((w.total_s() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn migration_gaps_are_migration_transit() {
        // A disaggregated handoff: prefill + first token on the
        // prefill engine, migrate_out → migrate_in over the
        // interconnect, then decode on the decode engine.
        let events = vec![
            ev(1, 0.0, 5, 1, EventKind::PrefillChunk),
            Event { a: 3, ..ev(2, 1.0, 5, 1, EventKind::MigrateOut) },
            Event { a: 3, ..ev(3, 1.5, 5, 1, EventKind::MigrateIn) },
            ev(4, 2.0, 5, 1, EventKind::DecodeIter),
            ev(5, 3.0, 5, 1, EventKind::DecodeIter),
            Event { fa: 0.5, fb: 4.0, ..ev(6, 4.0, 5, 1, EventKind::Finished) },
        ];
        let agg = ProfileAggregator::fold(ProfileConfig::default(), &events);
        let w = &agg.waterfalls()[0];
        // migrate_out→migrate_in (0.5s) + migrate_in→decode (0.5s).
        assert!((w.phases[Phase::MigrationTransit.idx()] - 1.0).abs() < 1e-9);
        assert!((w.phases[Phase::Prefill.idx()] - 1.0).abs() < 1e-9);
        assert!((w.phases[Phase::Decode.idx()] - 2.0).abs() < 1e-9);
        assert!((w.total_s() - 4.0).abs() < 1e-9, "partition stays exact");
        assert_eq!(
            w.signature,
            vec![
                (Phase::Prefill, 1),
                (Phase::MigrationTransit, 2),
                (Phase::Decode, 2)
            ]
        );
    }

    #[test]
    fn rolling_windows_burn_and_alerts_fire_on_breach() {
        let slo = 1.0;
        let cfg = ProfileConfig {
            slo_s: Some(slo),
            target: 0.9,
            short_window_s: 30.0,
            long_window_s: 120.0,
            alert_policy: AlertPolicy { min_samples: 5, ..AlertPolicy::default() },
        };
        let mut agg = ProfileAggregator::new(cfg);
        // 40 requests finishing on tier 0, all breaching the SLO.
        let mut seq = 0;
        for i in 0..40u64 {
            let t = i as f64 * 0.5;
            seq += 1;
            agg.observe(&ev(seq, t, i, 0, EventKind::DecodeIter));
            seq += 1;
            agg.observe(&Event {
                fa: 0.2,
                fb: 5.0,
                ..ev(seq, t + 0.2, i, 0, EventKind::Finished)
            });
        }
        let report = agg.report(0);
        assert_eq!(report.requests, 40);
        let t0 = &report.tiers[0];
        assert!(t0.attainment_short < 0.01, "all breached: {}", t0.attainment_short);
        assert!(t0.burn_short > 9.0, "burn {}", t0.burn_short);
        let slo_alerts: Vec<_> =
            report.alerts.iter().filter(|a| a.kind == AlertKind::SloBurnRate).collect();
        assert_eq!(slo_alerts.len(), 1, "edge-triggered: exactly one alert");
        assert_eq!(slo_alerts[0].tier, 0);
        // A second report with no new data must not re-fire.
        let report2 = agg.report(0);
        assert_eq!(
            report2.alerts.iter().filter(|a| a.kind == AlertKind::SloBurnRate).count(),
            1
        );
        // Drops surface as a trace-drops alert.
        let report3 = agg.report(17);
        assert!(report3.alerts.iter().any(|a| a.kind == AlertKind::TraceDrops));
    }

    #[test]
    fn hot_swap_system_events_are_counted_not_attributed() {
        let events = vec![
            ev(1, 0.0, 1, 0, EventKind::DecodeIter),
            ev(2, 0.5, REQ_NONE, 0, EventKind::HotSwapApplied),
            Event { fa: 0.1, fb: 1.0, ..ev(3, 1.0, 1, 0, EventKind::Finished) },
        ];
        let mut agg = ProfileAggregator::fold(ProfileConfig::default(), &events);
        let report = agg.report(0);
        assert_eq!(report.hot_swaps, 1);
        assert_eq!(report.requests, 1);
    }

    #[test]
    fn json_schema_has_the_documented_top_level_keys() {
        let events = vec![
            ev(1, 0.0, 1, 0, EventKind::DecodeIter),
            Event { fa: 0.1, fb: 1.0, ..ev(2, 1.0, 1, 0, EventKind::Finished) },
        ];
        let mut agg = ProfileAggregator::fold(
            ProfileConfig { slo_s: Some(10.0), ..ProfileConfig::default() },
            &events,
        );
        let json = agg.report(0).to_json();
        let parsed = crate::util::json::Json::parse(&json).expect("profile JSON must parse");
        for key in
            ["schema", "requests", "events", "e2e", "ttft", "attribution", "phases", "tiers", "alerts"]
        {
            assert!(parsed.get(key).is_some(), "missing key {key} in {json}");
        }
        assert_eq!(
            parsed.get("schema").and_then(|j| j.as_str()),
            Some("cascadia.profile.v1")
        );
        let render = agg.report(0).render();
        assert!(render.contains("latency attribution"));
        assert!(render.contains("queue"));
    }
}
