//! Timeline diff: align two traces per request and report the first
//! divergence.
//!
//! This is the DES↔live equivalence surface: the paged DES and the
//! live engine drive the same `IterationScheduler` and emit the same
//! plan-derived event schema, so for a deterministic workload their
//! per-request event sequences must be **identical up to timestamps**.
//! The diff compares [`Event::signature`]s (kind + integer payloads;
//! never `t`/`fa`/`fb`/`seq` — wall and simulated clocks legitimately
//! disagree) request by request and reports the first mismatch per
//! request plus requests present on only one side.

use std::collections::BTreeMap;

use super::{Event, REQ_NONE};

/// One per-request mismatch between the two timelines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Divergence {
    pub req: u64,
    /// Index into the request's event sequence where the sides first
    /// disagree.
    pub index: usize,
    /// Human-readable event signature on each side (`-` = side has no
    /// event at this index).
    pub left: String,
    pub right: String,
}

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req {} event #{}: left {} vs right {}",
            self.req, self.index, self.left, self.right
        )
    }
}

/// Outcome of a timeline diff.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    /// Requests present on both sides.
    pub requests_compared: usize,
    /// Requests appearing on exactly one side.
    pub only_left: Vec<u64>,
    pub only_right: Vec<u64>,
    /// First mismatch per diverging request, request order.
    pub divergences: Vec<Divergence>,
    pub events_left: usize,
    pub events_right: usize,
}

impl DiffReport {
    /// True when the timelines agree: same request set, same
    /// per-request event signature sequences.
    pub fn is_equivalent(&self) -> bool {
        self.divergences.is_empty() && self.only_left.is_empty() && self.only_right.is_empty()
    }

    /// The first divergence in request order, if any.
    pub fn first_divergence(&self) -> Option<&Divergence> {
        self.divergences.first()
    }
}

fn describe(ev: Option<&Event>) -> String {
    match ev {
        Some(e) => format!("{}(a={},b={},c={})", e.kind.name(), e.a, e.b, e.c),
        None => "-".to_string(),
    }
}

fn by_request(events: &[Event]) -> BTreeMap<u64, Vec<&Event>> {
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by_key(|e| e.seq);
    let mut map: BTreeMap<u64, Vec<&Event>> = BTreeMap::new();
    for e in sorted {
        if e.req != REQ_NONE {
            map.entry(e.req).or_default().push(e);
        }
    }
    map
}

/// Diff two event timelines per request. See the module docs.
pub fn diff_timelines(left: &[Event], right: &[Event]) -> DiffReport {
    let l = by_request(left);
    let r = by_request(right);
    let mut report = DiffReport {
        events_left: left.len(),
        events_right: right.len(),
        ..DiffReport::default()
    };
    for req in l.keys() {
        if !r.contains_key(req) {
            report.only_left.push(*req);
        }
    }
    for req in r.keys() {
        if !l.contains_key(req) {
            report.only_right.push(*req);
        }
    }
    for (req, lev) in &l {
        let Some(rev) = r.get(req) else { continue };
        report.requests_compared += 1;
        let n = lev.len().max(rev.len());
        for i in 0..n {
            let a = lev.get(i).copied();
            let b = rev.get(i).copied();
            let same = match (a, b) {
                (Some(x), Some(y)) => x.signature() == y.signature(),
                _ => false,
            };
            if !same {
                report.divergences.push(Divergence {
                    req: *req,
                    index: i,
                    left: describe(a),
                    right: describe(b),
                });
                break;
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::super::EventKind;
    use super::*;

    fn ev(seq: u64, req: u64, kind: EventKind, a: u64) -> Event {
        Event { seq, a, ..Event::at(seq as f64, req, 0, kind) }
    }

    #[test]
    fn identical_sequences_with_different_timestamps_are_equivalent() {
        let left = vec![
            ev(0, 1, EventKind::PrefillChunk, 8),
            ev(1, 1, EventKind::DecodeIter, 1),
            ev(2, 1, EventKind::Finished, 0),
        ];
        let mut right = left.clone();
        for (i, e) in right.iter_mut().enumerate() {
            e.t = 100.0 + i as f64; // timestamps differ wildly
            e.fa = 42.0;
        }
        let rep = diff_timelines(&left, &right);
        assert!(rep.is_equivalent(), "{:?}", rep.divergences);
        assert_eq!(rep.requests_compared, 1);
    }

    #[test]
    fn payload_mismatch_reports_first_divergence() {
        let left = vec![
            ev(0, 5, EventKind::PrefillChunk, 8),
            ev(1, 5, EventKind::DecodeIter, 2),
        ];
        let right = vec![
            ev(0, 5, EventKind::PrefillChunk, 8),
            ev(1, 5, EventKind::DecodeIter, 3),
        ];
        let rep = diff_timelines(&left, &right);
        assert!(!rep.is_equivalent());
        let d = rep.first_divergence().unwrap();
        assert_eq!((d.req, d.index), (5, 1));
        assert!(d.to_string().contains("decode_iter(a=2"), "{d}");
        assert!(d.to_string().contains("decode_iter(a=3"), "{d}");
    }

    #[test]
    fn length_mismatch_and_missing_requests_are_flagged() {
        let left = vec![ev(0, 1, EventKind::DecodeIter, 1), ev(1, 2, EventKind::DecodeIter, 1)];
        let right = vec![
            ev(0, 1, EventKind::DecodeIter, 1),
            ev(1, 1, EventKind::Finished, 0),
            ev(2, 3, EventKind::DecodeIter, 1),
        ];
        let rep = diff_timelines(&left, &right);
        assert_eq!(rep.only_left, vec![2]);
        assert_eq!(rep.only_right, vec![3]);
        let d = rep.first_divergence().unwrap();
        assert_eq!((d.req, d.index), (1, 1));
        assert_eq!(d.left, "-");
    }
}
