//! The unified metrics registry: counters, gauges, and fixed-bucket
//! histograms with Prometheus text exposition.
//!
//! The serve loop records every completion here (per-tier TTFT and
//! end-to-end latency histograms, request/escalation/hot-swap
//! counters) and derives its latency reporting from the retained
//! samples via [`LatencySummary`] — one collection point instead of
//! parallel `Vec<f64>`s. [`MetricsRegistry::render_prometheus`] emits
//! the text exposition format served by the `GET /metrics` frame on
//! [`TcpFrontend`](crate::coordinator::net::TcpFrontend).
//!
//! Metric keys are full series names including their label set, e.g.
//! `cascadia_ttft_seconds{tier="0"}` — the renderer splits the family
//! name back out for `# TYPE` lines and merges `le` into existing
//! labels for histogram buckets. Keys iterate in `BTreeMap` order, so
//! the exposition (and every derived report) is deterministic.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::metrics::LatencySummary;
use crate::util::sync::LockExt;

/// Default latency histogram upper bounds, seconds (a `+Inf` bucket is
/// implicit). Spans sub-millisecond engine ticks to the replay's
/// tens-of-seconds uncompressed tails.
pub const LATENCY_BUCKETS: &[f64] = &[
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
    50.0, 100.0,
];

/// One fixed-bucket histogram (plus retained raw samples so percentile
/// summaries stay exact rather than bucket-interpolated).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds, ascending; the overflow bucket is
    /// `counts[bounds.len()]`.
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
    samples: Vec<f64>,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
            samples: Vec::new(),
        }
    }

    fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&ub| v <= ub)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
        self.samples.push(v);
    }

    /// Exact percentile summary of the retained samples — the
    /// registry's histogram path reuses [`LatencySummary::of`] (and
    /// its `total_cmp` ordering) instead of reimplementing it.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary::of(&self.samples)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Counters, gauges, and histograms behind one lock each. Recording
/// happens at request granularity (admission/completion), not token
/// granularity — the per-token hot path goes through the trace
/// recorder's ring buffer instead.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    hists: Mutex<BTreeMap<String, Histogram>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `v` to counter `key` (created at 0).
    pub fn counter_add(&self, key: &str, v: u64) {
        *self.counters.plock().entry(key.to_string()).or_insert(0) += v;
    }

    /// Increment counter `key` by 1.
    pub fn inc(&self, key: &str) {
        self.counter_add(key, 1);
    }

    pub fn counter(&self, key: &str) -> u64 {
        self.counters.plock().get(key).copied().unwrap_or(0)
    }

    /// Set counter `key` to `v` — for sampled exports of sources that
    /// are already monotonic (e.g. the trace recorder's per-shard drop
    /// totals), where re-adding would double-count.
    pub fn counter_set(&self, key: &str, v: u64) {
        self.counters.plock().insert(key.to_string(), v);
    }

    /// Set gauge `key` to `v`.
    pub fn gauge_set(&self, key: &str, v: f64) {
        self.gauges.plock().insert(key.to_string(), v);
    }

    pub fn gauge(&self, key: &str) -> Option<f64> {
        self.gauges.plock().get(key).copied()
    }

    /// Record `v` into histogram `key`, creating it with `bounds` on
    /// first touch (later calls keep the original bounds).
    pub fn observe(&self, key: &str, bounds: &[f64], v: f64) {
        self.hists
            .plock()
            .entry(key.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    /// Exact percentile summary of histogram `key` (None if the series
    /// does not exist).
    pub fn summary(&self, key: &str) -> Option<LatencySummary> {
        self.hists.plock().get(key).map(|h| h.summary())
    }

    /// Retained raw samples of histogram `key`.
    pub fn samples(&self, key: &str) -> Vec<f64> {
        self.hists
            .plock()
            .get(key)
            .map(|h| h.samples().to_vec())
            .unwrap_or_default()
    }

    /// Total observations across every histogram series of `family`
    /// (series whose name before `{` equals `family`).
    pub fn family_count(&self, family: &str) -> u64 {
        self.hists
            .plock()
            .iter()
            .filter(|(k, _)| family_of(k) == family)
            .map(|(_, h)| h.count)
            .sum()
    }

    /// Render the Prometheus text exposition format (version 0.0.4):
    /// one `# TYPE` line per family, then its series in key order.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.plock();
        let mut last_family = "";
        for (key, v) in counters.iter() {
            let fam = family_of(key);
            if fam != last_family {
                out.push_str(&format!("# TYPE {fam} counter\n"));
                last_family = fam;
            }
            out.push_str(&format!("{key} {v}\n"));
        }
        drop(counters);
        let gauges = self.gauges.plock();
        let mut last_family = "";
        for (key, v) in gauges.iter() {
            let fam = family_of(key);
            if fam != last_family {
                out.push_str(&format!("# TYPE {fam} gauge\n"));
                last_family = fam;
            }
            out.push_str(&format!("{key} {v}\n"));
        }
        drop(gauges);
        let hists = self.hists.plock();
        let mut last_family = "";
        for (key, h) in hists.iter() {
            let fam = family_of(key);
            if fam != last_family {
                out.push_str(&format!("# TYPE {fam} histogram\n"));
                last_family = fam;
            }
            let mut cumulative = 0u64;
            for (i, &ub) in h.bounds.iter().enumerate() {
                cumulative += h.counts[i];
                out.push_str(&format!(
                    "{} {}\n",
                    series_with_le(key, &format!("{ub}")),
                    cumulative
                ));
            }
            cumulative += h.counts[h.bounds.len()];
            out.push_str(&format!("{} {}\n", series_with_le(key, "+Inf"), cumulative));
            out.push_str(&format!("{} {}\n", suffixed(key, "_sum"), h.sum));
            out.push_str(&format!("{} {}\n", suffixed(key, "_count"), h.count));
        }
        out
    }
}

/// Family name of a series key: everything before the label block.
fn family_of(key: &str) -> &str {
    key.split('{').next().unwrap_or(key)
}

/// `name{labels}` + `le` → `name_bucket{labels,le="..."}`.
fn series_with_le(key: &str, le: &str) -> String {
    match key.split_once('{') {
        Some((name, rest)) => {
            let labels = rest.trim_end_matches('}');
            if labels.is_empty() {
                format!("{name}_bucket{{le=\"{le}\"}}")
            } else {
                format!("{name}_bucket{{{labels},le=\"{le}\"}}")
            }
        }
        None => format!("{key}_bucket{{le=\"{le}\"}}"),
    }
}

/// `name{labels}` + suffix → `name_sum{labels}` etc.
fn suffixed(key: &str, suffix: &str) -> String {
    match key.split_once('{') {
        Some((name, rest)) => format!("{name}{suffix}{{{rest}"),
        None => format!("{key}{suffix}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let r = MetricsRegistry::new();
        r.inc("cascadia_requests_total");
        r.counter_add("cascadia_requests_total", 2);
        r.gauge_set("cascadia_tiers", 3.0);
        assert_eq!(r.counter("cascadia_requests_total"), 3);
        assert_eq!(r.gauge("cascadia_tiers"), Some(3.0));
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn histogram_buckets_and_summary_agree_with_latency_summary() {
        let r = MetricsRegistry::new();
        let vals: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        for &v in &vals {
            r.observe("lat{tier=\"0\"}", LATENCY_BUCKETS, v);
        }
        let s = r.summary("lat{tier=\"0\"}").unwrap();
        assert_eq!(s, LatencySummary::of(&vals), "histogram summary reuses LatencySummary");
        assert_eq!(r.family_count("lat"), 100);
        assert_eq!(r.samples("lat{tier=\"0\"}").len(), 100);
    }

    #[test]
    fn prometheus_rendering_has_types_buckets_and_cumulative_counts() {
        let r = MetricsRegistry::new();
        r.inc("reqs_total");
        r.gauge_set("pool_pages{tier=\"1\"}", 64.0);
        r.observe("ttft{tier=\"0\"}", &[0.1, 1.0], 0.05);
        r.observe("ttft{tier=\"0\"}", &[0.1, 1.0], 0.5);
        r.observe("ttft{tier=\"0\"}", &[0.1, 1.0], 5.0);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE reqs_total counter"));
        assert!(text.contains("reqs_total 1"));
        assert!(text.contains("# TYPE pool_pages gauge"));
        assert!(text.contains("pool_pages{tier=\"1\"} 64"));
        assert!(text.contains("# TYPE ttft histogram"));
        assert!(text.contains("ttft_bucket{tier=\"0\",le=\"0.1\"} 1"));
        assert!(text.contains("ttft_bucket{tier=\"0\",le=\"1\"} 2"));
        assert!(text.contains("ttft_bucket{tier=\"0\",le=\"+Inf\"} 3"));
        assert!(text.contains("ttft_count{tier=\"0\"} 3"));
        assert!(text.contains("ttft_sum{tier=\"0\"}"));
    }

    #[test]
    fn bare_series_render_without_label_block() {
        let r = MetricsRegistry::new();
        r.observe("e2e", &[1.0], 0.5);
        let text = r.render_prometheus();
        assert!(text.contains("e2e_bucket{le=\"1\"} 1"));
        assert!(text.contains("e2e_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("e2e_sum 0.5"));
        assert!(text.contains("e2e_count 1"));
    }
}
