//! The span recorder: a bounded, sharded ring buffer of trace events.
//!
//! One shard per worker thread (the emitting worker indexes its own
//! shard, so shard mutexes are effectively uncontended); each shard is
//! a fixed-capacity ring that **drops oldest** on overflow and counts
//! what it dropped — a trace can always tell you it is incomplete, and
//! an overflowing shard never corrupts the events still in the ring.
//! `Event` is `Copy` and the ring is pre-allocated, so the emit path
//! performs no heap allocation.
//!
//! Global ordering: every emit draws a sequence number from one
//! atomic counter, so a merged [`TraceRecorder::snapshot`] has a total
//! order even across shards, and a single-threaded emitter (the DES,
//! a directly-driven engine) gets a deterministic sequence.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::sync::LockExt;

use super::Event;

/// Default per-shard capacity (events). At ~80 bytes per event this is
/// ~5 MiB per shard — enough for the bench and replay workloads
/// without trimming, small enough to pin resident.
pub const DEFAULT_SHARD_CAP: usize = 65_536;

struct Shard {
    /// Pre-allocated ring storage (never grows past `cap`).
    buf: Vec<Event>,
    /// Index of the oldest retained event.
    head: usize,
    /// Retained events.
    len: usize,
    /// Events overwritten by drop-oldest overflow.
    dropped: u64,
}

impl Shard {
    fn push(&mut self, ev: Event, cap: usize) {
        if self.len < cap {
            let slot = (self.head + self.len) % cap;
            if slot == self.buf.len() {
                // Still filling the pre-allocated capacity: a push
                // within `Vec::with_capacity` never reallocates.
                self.buf.push(ev);
            } else {
                self.buf[slot] = ev;
            }
            self.len += 1;
        } else {
            // Full: overwrite the oldest event and count the drop.
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % cap;
            self.dropped += 1;
        }
    }

    fn iter_in_order(&self, cap: usize) -> impl Iterator<Item = Event> + '_ {
        (0..self.len).map(move |i| self.buf[(self.head + i) % cap])
    }
}

/// One shard's health snapshot (see [`TraceRecorder::shard_stats`]).
#[derive(Debug, Clone, Copy)]
pub struct ShardStats {
    /// Events currently retained in the ring.
    pub retained: usize,
    /// Events lost to drop-oldest overflow (monotonic).
    pub dropped: u64,
    /// Ring capacity.
    pub cap: usize,
}

/// Bounded multi-shard trace recorder. See the module docs.
pub struct TraceRecorder {
    shards: Vec<Mutex<Shard>>,
    cap_per_shard: usize,
    next_seq: AtomicU64,
}

impl TraceRecorder {
    /// `n_shards` worker shards of `cap_per_shard` events each (both
    /// floored at 1).
    pub fn new(n_shards: usize, cap_per_shard: usize) -> TraceRecorder {
        let cap = cap_per_shard.max(1);
        let shards = (0..n_shards.max(1))
            .map(|_| {
                Mutex::new(Shard {
                    buf: Vec::with_capacity(cap),
                    head: 0,
                    len: 0,
                    dropped: 0,
                })
            })
            .collect();
        TraceRecorder { shards, cap_per_shard: cap, next_seq: AtomicU64::new(0) }
    }

    /// One shard per tier with the default capacity.
    pub fn for_tiers(n_tiers: usize) -> TraceRecorder {
        TraceRecorder::new(n_tiers, DEFAULT_SHARD_CAP)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Record one event on `shard` (wrapped into range). Assigns the
    /// global sequence number; drop-oldest on a full shard.
    pub fn emit(&self, shard: usize, mut ev: Event) {
        ev.seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let s = &self.shards[shard % self.shards.len()];
        s.plock().push(ev, self.cap_per_shard);
    }

    /// Events currently retained across all shards.
    pub fn n_events(&self) -> usize {
        self.shards.iter().map(|s| s.plock().len).sum()
    }

    /// Events lost to ring overflow across all shards.
    pub fn dropped_events(&self) -> u64 {
        self.shards.iter().map(|s| s.plock().dropped).sum()
    }

    /// Per-shard health: retained events, drop total, and capacity —
    /// the `/metrics` export surface ([`super::export_recorder_health`]).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|s| {
                let g = s.plock();
                ShardStats { retained: g.len, dropped: g.dropped, cap: self.cap_per_shard }
            })
            .collect()
    }

    /// Merged copy of every retained event, in global emission order.
    pub fn snapshot(&self) -> Vec<Event> {
        let mut out: Vec<Event> = Vec::with_capacity(self.n_events());
        for s in &self.shards {
            let g = s.plock();
            out.extend(g.iter_in_order(self.cap_per_shard));
        }
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Retained events grouped by request id, each group in emission
    /// order ([`super::REQ_NONE`] system events excluded).
    pub fn per_request(&self) -> BTreeMap<u64, Vec<Event>> {
        let mut map: BTreeMap<u64, Vec<Event>> = BTreeMap::new();
        for ev in self.snapshot() {
            if ev.req != super::REQ_NONE {
                map.entry(ev.req).or_default().push(ev);
            }
        }
        map
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Event, EventKind, REQ_NONE};
    use super::*;

    fn ev(t: f64, req: u64) -> Event {
        Event::at(t, req, 0, EventKind::DecodeIter)
    }

    #[test]
    fn snapshot_preserves_emission_order_across_shards() {
        let rec = TraceRecorder::new(3, 16);
        for i in 0..9u64 {
            rec.emit((i % 3) as usize, ev(i as f64, i));
        }
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 9);
        let reqs: Vec<u64> = snap.iter().map(|e| e.req).collect();
        assert_eq!(reqs, (0..9).collect::<Vec<_>>(), "global order survives sharding");
        assert_eq!(rec.dropped_events(), 0);
    }

    #[test]
    fn overflow_drops_oldest_and_counts_without_corruption() {
        let rec = TraceRecorder::new(1, 4);
        for i in 0..10u64 {
            rec.emit(0, ev(i as f64, i));
        }
        assert_eq!(rec.n_events(), 4, "ring keeps exactly its capacity");
        assert_eq!(rec.dropped_events(), 6, "every overwritten event is counted");
        let snap = rec.snapshot();
        let reqs: Vec<u64> = snap.iter().map(|e| e.req).collect();
        assert_eq!(reqs, vec![6, 7, 8, 9], "the newest events survive, in order");
    }

    #[test]
    fn per_request_groups_and_skips_system_events() {
        let rec = TraceRecorder::new(2, 16);
        rec.emit(0, ev(0.0, 7));
        rec.emit(1, ev(1.0, 8));
        rec.emit(0, ev(2.0, 7));
        rec.emit(0, ev(3.0, REQ_NONE));
        let by_req = rec.per_request();
        assert_eq!(by_req.len(), 2);
        assert_eq!(by_req[&7].len(), 2);
        assert!(by_req[&7][0].seq < by_req[&7][1].seq);
        assert_eq!(by_req[&8].len(), 1);
    }

    #[test]
    fn shard_index_wraps_instead_of_panicking() {
        let rec = TraceRecorder::new(2, 4);
        rec.emit(17, ev(0.0, 1));
        assert_eq!(rec.n_events(), 1);
    }
}
