//! Chrome trace-event JSON export (the "JSON Array Format" with a
//! `traceEvents` wrapper), loadable in `chrome://tracing` and
//! Perfetto.
//!
//! Mapping: one **process per tier** (`pid` = tier, named
//! `tier-N`), one **track per request** (`tid` = request id), so an
//! engine tick's admit/preempt/swap interleaving is visually
//! inspectable per tier while escalation chains stay aligned on the
//! request's track. Every trace event becomes an instant (`ph: "i"`)
//! with its payloads under `args`; requests that have both an
//! `admitted` and a `finished` event additionally get a complete span
//! (`ph: "X"`) stretching across their lifetime. Timestamps are the
//! recorder's seconds scaled to microseconds (the format's unit).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{Event, EventKind, REQ_NONE};

/// Convert a snapshot of trace events into a Chrome trace-event JSON
/// document.
pub fn chrome_trace(events: &[Event]) -> Json {
    let mut items: Vec<Json> = Vec::with_capacity(events.len() + 16);

    // Process name metadata: one per tier seen.
    let mut tiers: Vec<u32> = events.iter().map(|e| e.tier).collect();
    tiers.sort_unstable();
    tiers.dedup();
    for t in &tiers {
        items.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(*t as f64)),
            ("tid", Json::num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("tier-{t}")))]),
            ),
        ]));
    }

    // Per-request lifetime spans: admitted .. finished.
    let mut admitted: BTreeMap<u64, &Event> = BTreeMap::new();
    for e in events {
        if e.kind == EventKind::Admitted && e.req != REQ_NONE {
            admitted.entry(e.req).or_insert(e);
        }
    }
    for e in events {
        if e.kind == EventKind::Finished && e.req != REQ_NONE {
            if let Some(adm) = admitted.get(&e.req) {
                let dur_us = ((e.t - adm.t).max(0.0)) * 1e6;
                items.push(Json::obj(vec![
                    ("name", Json::str(format!("request-{}", e.req))),
                    ("cat", Json::str("request")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(adm.t * 1e6)),
                    ("dur", Json::num(dur_us)),
                    ("pid", Json::num(adm.tier as f64)),
                    ("tid", Json::num(e.req as f64)),
                    (
                        "args",
                        Json::obj(vec![
                            ("ttft_s", Json::num(e.fa)),
                            ("latency_s", Json::num(e.fb)),
                            ("accepting_tier", Json::num(e.tier as f64)),
                        ]),
                    ),
                ]));
            }
        }
    }

    // Every event as an instant on its request's track.
    for e in events {
        let tid = if e.req == REQ_NONE { 0.0 } else { e.req as f64 };
        items.push(Json::obj(vec![
            ("name", Json::str(e.kind.name())),
            ("cat", Json::str("cascadia")),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::num(e.t * 1e6)),
            ("pid", Json::num(e.tier as f64)),
            ("tid", Json::num(tid)),
            (
                "args",
                Json::obj(vec![
                    ("a", Json::num(e.a as f64)),
                    ("b", Json::num(e.b as f64)),
                    ("c", Json::num(e.c as f64)),
                    ("fa", Json::num(e.fa)),
                    ("fb", Json::num(e.fb)),
                    ("seq", Json::num(e.seq as f64)),
                ]),
            ),
        ]));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(items)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::Event;
    use super::*;

    #[test]
    fn export_wraps_events_and_round_trips_as_json() {
        let events = vec![
            Event::at(0.001, 7, 0, EventKind::Admitted),
            Event { a: 16, c: 1, ..Event::at(0.002, 7, 0, EventKind::PrefillChunk) },
            Event { fa: 0.003, fb: 0.01, ..Event::at(0.011, 7, 1, EventKind::Finished) },
        ];
        let doc = chrome_trace(&events);
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        // 1 process meta per tier (2 tiers) + 1 request span + 3 instants.
        assert_eq!(arr.len(), 6);
        let span = arr
            .iter()
            .find(|e| {
                e.get("ph").and_then(|p| p.as_str().ok().map(|s| s.to_string()))
                    == Some("X".to_string())
            })
            .expect("request span present");
        assert_eq!(span.req("tid").unwrap().as_i64().unwrap(), 7);
        // 10 ms lifetime in microseconds.
        assert!((span.req("dur").unwrap().as_f64().unwrap() - 10_000.0).abs() < 1.0);
        let names: Vec<String> = arr
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str().ok().map(String::from)))
            .collect();
        assert!(names.iter().any(|n| n == "prefill_chunk"));
        assert!(names.iter().any(|n| n == "tier-1"));
    }

    #[test]
    fn unfinished_requests_export_without_a_span() {
        let events = vec![Event::at(0.0, 3, 0, EventKind::Admitted)];
        let doc = chrome_trace(&events);
        let arr_len = doc.req("traceEvents").unwrap().as_arr().unwrap().len();
        assert_eq!(arr_len, 2, "one process meta + one instant, no span");
    }
}
