//! The `cascadia-lint` rules: a guard-tracking walk over the token
//! stream plus per-file scopes and the allow-annotation grammar.
//!
//! Four rule families (see `DESIGN.md` §"Static analysis & concurrency
//! discipline" for the full contract):
//!
//! * `lock-order` — nested acquisitions must move strictly down
//!   [`LOCK_HIERARCHY`]; same-lock re-entry and statement-adjacent
//!   re-acquisition (lock churn) are flagged too.
//! * `blocking-under-lock` — no `recv`/`recv_timeout`/`join`/`sleep`/
//!   `generate`/`step`/`prefill_chunk` call while any guard is held
//!   (`Condvar::wait` is exempt: it atomically releases the mutex).
//! * `hot-path-unwrap` — no `.unwrap()`/`.expect()` in `engine/` and
//!   `coordinator/` non-test code.
//! * `determinism` — no `HashMap`/`HashSet`, `Instant::now`/
//!   `SystemTime::now`, or float-literal `==`/`!=` in `sim/`, `sched/`,
//!   `engine/scheduler.rs`, `engine/migrate.rs`, and `obs/` non-test
//!   code (the DES↔engine equivalence pins replay these modules — the
//!   disagg DES models the hub's exact routing — and the DES emits
//!   trace events through `obs/`). Exception: `obs/clock.rs` is the
//!   designated wall-clock boundary and may read `Instant::now`.
//!
//! Suppression: a line comment carrying the `cascadia-lint` marker
//! (tool name, then a colon) followed by `allow(<rule>, reason =
//! "...")`, placed on the violating line or the line above. The reason
//! is mandatory and non-empty; a malformed directive is itself
//! reported (rule `bad-annotation`) and cannot be suppressed.
//!
//! The tracker is intentionally lexical: guards are recognized by the
//! `.lock()`/`.read()`/`.write()` (and poison-panicking `plock`/
//! `pread`/`pwrite`) call shape with empty parens, bound to a scope,
//! a `match`/`if let` block, or the enclosing statement (temporaries),
//! and released by `}` / `;` / `drop(var)`. It does not chase calls
//! across functions — the hierarchy is the cross-function contract.
//!
//! `scripts/cascadia_lint_mirror.py` re-implements these rules
//! one-to-one for toolchain-free environments; keep the two in
//! lockstep.

use super::lexer::{lex, Comment, Kind, Token};

/// Public rule IDs, valid in `allow(...)` directives.
pub const RULES: [&str; 4] =
    ["lock-order", "blocking-under-lock", "hot-path-unwrap", "determinism"];

/// Reported when an `allow` directive itself is malformed. Not a valid
/// `allow` target — annotation errors are unsuppressable.
pub const BAD_ANNOTATION: &str = "bad-annotation";

/// The declared lock hierarchy, outermost tier first: a thread holding
/// a lock from tier `i` may only take locks from tiers `> i`. Deleting
/// this declaration makes [`super::lint_tree`] (and the tree-clean
/// test) fail — the hierarchy is load-bearing, not documentation.
pub const LOCK_HIERARCHY: &[&[&str]] =
    &[&["pending"], &["batcher"], &["queue_time", "first_tokens"], &["policy"]];

/// Guard-producing method names (empty-parens call shape). The p-forms
/// are `util::sync`'s poison-panicking wrappers.
const ACQUIRE_METHODS: [&str; 6] = ["lock", "read", "write", "plock", "pread", "pwrite"];

/// Calls that block (or can block arbitrarily long) — illegal while any
/// guard is held. `wait` is deliberately absent: `Condvar::wait(guard)`
/// atomically releases the mutex and is the blessed blocking pattern.
const BLOCKING_CALLS: [&str; 7] =
    ["recv", "recv_timeout", "join", "sleep", "generate", "step", "prefill_chunk"];

const UNWRAP_METHODS: [&str; 2] = ["unwrap", "expect"];

/// One lint finding in one file.
#[derive(Debug, Clone)]
pub struct Violation {
    /// 1-based source line.
    pub line: usize,
    /// Rule ID (one of [`RULES`] or [`BAD_ANNOTATION`]).
    pub rule: &'static str,
    pub message: String,
}

/// Is `rel` (src-relative, `/`-separated) under the unwrap ban?
fn unwrap_scope(rel: &str) -> bool {
    rel.starts_with("engine/") || rel.starts_with("coordinator/")
}

/// Is `rel` inside the determinism-pinned modules? `obs/` is pinned
/// because the DES emits through it (shared tracing path), EXCEPT
/// `obs/clock.rs` — the designated wall-clock boundary, the one place
/// allowed to read `Instant::now`. `engine/spec.rs` is pinned because
/// the DES models draft agreement with the same pure function the
/// live [`crate::engine::SpecPair`] replays through — ambient
/// randomness or wall-clock there would break the DES↔live
/// accepted/rejected-count pin.
fn determinism_scope(rel: &str) -> bool {
    rel.starts_with("sim/")
        || rel.starts_with("sched/")
        || rel == "engine/scheduler.rs"
        || rel == "engine/migrate.rs"
        || rel == "engine/spec.rs"
        || (rel.starts_with("obs/") && rel != "obs/clock.rs")
}

/// Tier index of `name` in [`LOCK_HIERARCHY`], if declared.
pub fn hierarchy_rank(name: &str) -> Option<usize> {
    LOCK_HIERARCHY.iter().position(|tier| tier.contains(&name))
}

/// Map a receiver ident onto its declared lock name: exact match, else
/// strip an `_ref`/`_arc` suffix (borrowed/shared handles to the same
/// lock, e.g. `policy_ref`).
fn normalize_lock_name(name: &str) -> String {
    if hierarchy_rank(name).is_some() {
        return name.to_string();
    }
    for suffix in ["_ref", "_arc"] {
        if let Some(stripped) = name.strip_suffix(suffix) {
            if hierarchy_rank(stripped).is_some() {
                return stripped.to_string();
            }
        }
    }
    name.to_string()
}

/// Extract `allow` grants from the line comments. A grant covers the
/// directive's own line and the next line. Malformed directives come
/// back as [`BAD_ANNOTATION`] violations.
fn parse_directives(comments: &[Comment]) -> (Vec<(usize, &'static str)>, Vec<Violation>) {
    let mut allows: Vec<(usize, &'static str)> = Vec::new();
    let mut errors: Vec<Violation> = Vec::new();
    for c in comments {
        let Some(pos) = c.text.find("cascadia-lint:") else { continue };
        let rest = c.text[pos + "cascadia-lint:".len()..].trim();
        match parse_allow(rest) {
            Ok((rule, _reason)) => {
                allows.push((c.line, rule));
                allows.push((c.line + 1, rule));
            }
            Err(msg) => errors.push(Violation {
                line: c.line,
                rule: BAD_ANNOTATION,
                message: msg.to_string(),
            }),
        }
    }
    (allows, errors)
}

/// Grammar: `allow(<rule>, reason = "<non-empty>")`. Returns the
/// canonical rule ID and the reason.
fn parse_allow(rest: &str) -> Result<(&'static str, &str), &'static str> {
    let inner = rest
        .strip_prefix("allow(")
        .and_then(|r| r.strip_suffix(')'))
        .ok_or("directive must be exactly `allow(<rule>, reason = \"...\")`")?;
    let comma = inner.find(',').ok_or("missing `, reason = \"...\"`")?;
    let rule_txt = inner[..comma].trim();
    let rule = *RULES
        .iter()
        .find(|r| **r == rule_txt)
        .ok_or("unknown rule in allow(...)")?;
    let tail = inner[comma + 1..].trim();
    let tail = tail.strip_prefix("reason").ok_or("missing `reason`")?.trim_start();
    let tail = tail.strip_prefix('=').ok_or("missing `=` after `reason`")?.trim_start();
    let reason = tail
        .strip_prefix('"')
        .and_then(|t| t.strip_suffix('"'))
        .ok_or("reason must be a double-quoted string")?;
    if reason.trim().is_empty() {
        return Err("reason must not be empty");
    }
    Ok((rule, reason))
}

/// A live lock guard as the tracker models it.
struct Guard {
    /// Normalized receiver name (None when the receiver is not a plain
    /// ident, e.g. a call result).
    name: Option<String>,
    rank: Option<usize>,
    /// `let` binding, when known — released by `drop(var)`.
    var: Option<String>,
    /// Brace depth the guard lives at; released when that block closes.
    depth: usize,
    /// Temporary (un-bound) guard: released at the statement boundary.
    temp: bool,
    line: usize,
}

/// `toks[j]`, treating negative and out-of-range indices as absent.
fn tok_at(toks: &[Token], j: i64) -> Option<&Token> {
    if j < 0 {
        None
    } else {
        toks.get(j as usize)
    }
}

fn is_punct(t: Option<&Token>, s: &str) -> bool {
    matches!(t, Some(t) if t.kind == Kind::Punct && t.text == s)
}

fn ident_text<'a>(t: Option<&'a Token>) -> Option<&'a str> {
    match t {
        Some(t) if t.kind == Kind::Ident => Some(&t.text),
        _ => None,
    }
}

/// `j` points just past an acquisition's `()`; skip `.unwrap()` /
/// `.expect(...)` chain links, returning the next token's index.
fn skip_unwrap_chain(toks: &[Token], mut j: i64) -> i64 {
    loop {
        let is_link = is_punct(tok_at(toks, j), ".")
            && matches!(ident_text(tok_at(toks, j + 1)), Some(t) if UNWRAP_METHODS.contains(&t))
            && is_punct(tok_at(toks, j + 2), "(");
        if !is_link {
            return j;
        }
        let mut pdepth = 1usize;
        let mut k = (j + 3) as usize;
        while k < toks.len() && pdepth > 0 {
            if toks[k].kind == Kind::Punct && toks[k].text == "(" {
                pdepth += 1;
            } else if toks[k].kind == Kind::Punct && toks[k].text == ")" {
                pdepth -= 1;
            }
            k += 1;
        }
        j = k as i64;
    }
}

/// Run every rule over one file's token stream (annotation filtering
/// happens in [`lint_source`]).
fn lint_tokens(rel: &str, toks: &[Token]) -> Vec<Violation> {
    let mut out: Vec<Violation> = Vec::new();
    let in_unwrap = unwrap_scope(rel);
    let in_det = determinism_scope(rel);

    let mut depth = 0usize;
    let mut guards: Vec<Guard> = Vec::new();
    // Brace depths of `#[test]`/`#[cfg(test)]`-gated blocks we are in.
    let mut test_stack: Vec<usize> = Vec::new();
    let mut pending_test = false;
    let mut pending_let_var: Option<String> = None;
    // Lock names temp-acquired by the previous statement (churn rule).
    let mut last_stmt: Option<(Vec<String>, usize)> = None;
    let mut cur_stmt: Vec<String> = Vec::new();

    let mut i: i64 = 0;
    while (i as usize) < toks.len() {
        let t = &toks[i as usize];
        let in_test = !test_stack.is_empty();

        // Attributes: skip their tokens entirely; an ident `test`
        // anywhere inside an outer attribute gates the next block.
        if t.kind == Kind::Punct && t.text == "#" {
            let inner = is_punct(tok_at(toks, i + 1), "!");
            let open_at = if inner { i + 2 } else { i + 1 };
            if is_punct(tok_at(toks, open_at), "[") {
                let mut bdepth = 1usize;
                let mut k = (open_at + 1) as usize;
                let mut saw_test = false;
                while k < toks.len() && bdepth > 0 {
                    let tk = &toks[k];
                    if tk.kind == Kind::Punct && tk.text == "[" {
                        bdepth += 1;
                    } else if tk.kind == Kind::Punct && tk.text == "]" {
                        bdepth -= 1;
                    } else if tk.kind == Kind::Ident && tk.text == "test" {
                        saw_test = true;
                    }
                    k += 1;
                }
                if saw_test && !inner {
                    pending_test = true;
                }
                i = k as i64;
                continue;
            }
        }

        if t.kind == Kind::Punct && t.text == "{" {
            depth += 1;
            if pending_test {
                test_stack.push(depth);
                pending_test = false;
            }
            last_stmt = None;
            cur_stmt.clear();
        } else if t.kind == Kind::Punct && t.text == "}" {
            guards.retain(|g| g.depth < depth);
            if test_stack.last() == Some(&depth) {
                test_stack.pop();
            }
            depth = depth.saturating_sub(1);
            last_stmt = None;
            cur_stmt.clear();
        } else if t.kind == Kind::Punct && t.text == ";" {
            guards.retain(|g| !(g.temp && g.depth == depth));
            last_stmt = Some((std::mem::take(&mut cur_stmt), depth));
            pending_let_var = None;
            pending_test = false;
        } else if t.kind == Kind::Punct && t.text == "=>" {
            last_stmt = None;
            cur_stmt.clear();
        } else if t.kind == Kind::Ident && t.text == "let" {
            let mut nxt = tok_at(toks, i + 1);
            if matches!(ident_text(nxt), Some("mut")) {
                nxt = tok_at(toks, i + 2);
            }
            pending_let_var = ident_text(nxt).map(|s| s.to_string());
        } else if t.kind == Kind::Ident
            && t.text == "drop"
            && is_punct(tok_at(toks, i + 1), "(")
            && ident_text(tok_at(toks, i + 2)).is_some()
            && is_punct(tok_at(toks, i + 3), ")")
        {
            let var = ident_text(tok_at(toks, i + 2)).map(|s| s.to_string());
            guards.retain(|g| g.var != var);
        }

        // Lock acquisition: `.lock()` etc with EMPTY parens (the std
        // Mutex/RwLock methods take no arguments, which is what keeps
        // io-style `read(buf)`/`write(buf)` calls out).
        if t.kind == Kind::Punct
            && t.text == "."
            && matches!(
                ident_text(tok_at(toks, i + 1)),
                Some(m) if ACQUIRE_METHODS.contains(&m)
            )
            && is_punct(tok_at(toks, i + 2), "(")
            && is_punct(tok_at(toks, i + 3), ")")
            && !in_test
        {
            let line = tok_at(toks, i + 1).map_or(t.line, |m| m.line);
            let name: Option<String> = ident_text(tok_at(toks, i - 1)).map(normalize_lock_name);
            let rank = name.as_deref().and_then(hierarchy_rank);
            // (a) same-lock re-entry while a guard is live.
            if let Some(n) = name.as_deref() {
                if let Some(g) = guards.iter().find(|g| g.name.as_deref() == Some(n)) {
                    out.push(Violation {
                        line,
                        rule: "lock-order",
                        message: format!(
                            "`{n}` re-acquired while already held (guard taken on \
                             line {}): deadlock",
                            g.line
                        ),
                    });
                }
            }
            // (b) nesting must move strictly down the hierarchy.
            if let (Some(n), Some(r)) = (name.as_deref(), rank) {
                if let Some(g) = guards.iter().find(|g| {
                    g.rank.is_some_and(|gr| r <= gr) && g.name.as_deref() != Some(n)
                }) {
                    out.push(Violation {
                        line,
                        rule: "lock-order",
                        message: format!(
                            "`{n}` (tier {r}) acquired while holding `{}` (tier {}, \
                             line {}): out of declared hierarchy order",
                            g.name.as_deref().unwrap_or("<unnamed>"),
                            g.rank.unwrap_or(0),
                            g.line
                        ),
                    });
                }
            }
            // Binding shape decides the guard's lifetime.
            let j = skip_unwrap_chain(toks, i + 4);
            if is_punct(tok_at(toks, j), ";") {
                guards.push(Guard {
                    name: name.clone(),
                    rank,
                    var: pending_let_var.clone(),
                    depth,
                    temp: false,
                    line,
                });
            } else if is_punct(tok_at(toks, j), "{") {
                guards.push(Guard {
                    name: name.clone(),
                    rank,
                    var: None,
                    depth: depth + 1,
                    temp: false,
                    line,
                });
            } else {
                // (c) statement-adjacent churn: the previous statement
                // took and dropped this same lock.
                if let Some(n) = name.as_deref() {
                    if let Some((locks, d)) = &last_stmt {
                        if *d == depth && locks.iter().any(|l| l == n) {
                            out.push(Violation {
                                line,
                                rule: "lock-order",
                                message: format!(
                                    "`{n}` re-acquired immediately after the previous \
                                     statement released it: take one guard and reuse it"
                                ),
                            });
                        }
                    }
                    cur_stmt.push(n.to_string());
                }
                guards.push(Guard {
                    name: name.clone(),
                    rank,
                    var: None,
                    depth,
                    temp: true,
                    line,
                });
            }
        }

        // Blocking call while any guard is held.
        if t.kind == Kind::Ident
            && BLOCKING_CALLS.contains(&t.text.as_str())
            && is_punct(tok_at(toks, i + 1), "(")
            && !guards.is_empty()
            && !in_test
        {
            let held: Vec<String> = guards
                .iter()
                .map(|g| match g.name.as_deref() {
                    Some(n) => format!("`{n}`"),
                    None => "<unnamed>".to_string(),
                })
                .collect();
            out.push(Violation {
                line: t.line,
                rule: "blocking-under-lock",
                message: format!(
                    "`{}()` called while holding {}: a blocked worker starves every \
                     other thread contending for the guard",
                    t.text,
                    held.join(", ")
                ),
            });
        }

        // Hot-path unwrap/expect ban.
        if in_unwrap
            && !in_test
            && t.kind == Kind::Ident
            && UNWRAP_METHODS.contains(&t.text.as_str())
            && is_punct(tok_at(toks, i - 1), ".")
            && is_punct(tok_at(toks, i + 1), "(")
        {
            out.push(Violation {
                line: t.line,
                rule: "hot-path-unwrap",
                message: format!(
                    "`.{}()` on an engine/coordinator hot path: handle the failure \
                     or annotate the invariant",
                    t.text
                ),
            });
        }

        // Determinism surface.
        if in_det && !in_test {
            if t.kind == Kind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                out.push(Violation {
                    line: t.line,
                    rule: "determinism",
                    message: format!(
                        "`{}` in a determinism-pinned module: iteration order is \
                         unstable; use BTreeMap/BTreeSet or annotate",
                        t.text
                    ),
                });
            }
            if t.kind == Kind::Ident
                && (t.text == "Instant" || t.text == "SystemTime")
                && is_punct(tok_at(toks, i + 1), "::")
                && matches!(ident_text(tok_at(toks, i + 2)), Some("now"))
            {
                out.push(Violation {
                    line: t.line,
                    rule: "determinism",
                    message: format!(
                        "`{}::now()` in a determinism-pinned module: wall clock \
                         reads break DES/engine replay equivalence",
                        t.text
                    ),
                });
            }
            if t.kind == Kind::Punct && (t.text == "==" || t.text == "!=") {
                let float_adj = matches!(
                    tok_at(toks, i - 1),
                    Some(p) if p.kind == Kind::Float
                ) || matches!(
                    tok_at(toks, i + 1),
                    Some(q) if q.kind == Kind::Float
                );
                if float_adj {
                    out.push(Violation {
                        line: t.line,
                        rule: "determinism",
                        message: "direct f64 comparison against a literal: use an \
                                  epsilon or restructure"
                            .to_string(),
                    });
                }
            }
        }
        i += 1;
    }
    out
}

/// Lint one file's source against its src-relative path (which selects
/// the per-file rule scopes). Returns surviving violations, sorted by
/// (line, rule).
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let (toks, comments) = lex(src);
    let (allows, bad) = parse_directives(&comments);
    let mut violations: Vec<Violation> = lint_tokens(rel, &toks)
        .into_iter()
        .filter(|v| !allows.contains(&(v.line, v.rule)))
        .collect();
    violations.extend(bad);
    violations.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    violations
}
