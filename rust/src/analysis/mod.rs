//! # cascadia-lint — in-repo concurrency & determinism static analysis
//!
//! A self-contained static-analysis pass over this crate's own source
//! tree. The serving engine is a lock-heavy multi-threaded system whose
//! scheduling layers are pinned by determinism-sensitive equivalence
//! tests; generic tooling does not know which locks nest, which calls
//! block, or which modules must replay bit-identically — so the rules
//! live in-repo, next to the code they police, and run under plain
//! `cargo test` (the tree-clean test below) as well as through the
//! `cascadia-lint` binary in CI.
//!
//! Layout:
//!
//! * [`lexer`] — a token-level Rust lexer (comments, strings, chars,
//!   lifetimes, numbers, greedy multi-char operators); built by hand
//!   because the crate is `anyhow`-only and must build offline, so
//!   `syn`-style parsing is not on the table.
//! * [`lints`] — the four rule families over the token stream: the
//!   guard-tracking `lock-order` checks against [`LOCK_HIERARCHY`],
//!   `blocking-under-lock`, `hot-path-unwrap`, and `determinism`;
//!   plus the `allow(<rule>, reason = "...")` annotation grammar.
//! * [`lint_tree`] — walk a source root and lint every `.rs` file.
//!
//! `scripts/cascadia_lint_mirror.py` mirrors the whole pass in Python
//! for toolchain-free environments. The Rust implementation is
//! authoritative; every rule change lands in both.

pub mod lexer;
pub mod lints;

pub use lints::{
    hierarchy_rank, lint_source, Violation, BAD_ANNOTATION, LOCK_HIERARCHY, RULES,
};

use anyhow::{bail, Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// The result of linting a source tree.
#[derive(Debug)]
pub struct TreeReport {
    /// How many `.rs` files were scanned.
    pub files: usize,
    /// `(src-relative path, violation)`, in (path, line, rule) order.
    pub violations: Vec<(String, Violation)>,
}

impl TreeReport {
    /// Render violations one per line, `rel:line: [rule] message`.
    pub fn render(&self) -> Vec<String> {
        self.violations
            .iter()
            .map(|(rel, v)| format!("{rel}:{}: [{}] {}", v.line, v.rule, v.message))
            .collect()
    }
}

/// Recursively collect `.rs` files under `dir`, sorted at every level
/// so reports (and CI logs) are stable across filesystems.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (the crate's `src/` directory).
/// Fails outright — rather than passing vacuously — if the lock
/// hierarchy declaration has been emptied out: the hierarchy is the
/// contract the `lock-order` rule enforces.
// The emptiness check IS the gate: deleting the declaration must fail.
#[allow(clippy::const_is_empty)]
pub fn lint_tree(root: &Path) -> Result<TreeReport> {
    if LOCK_HIERARCHY.is_empty() {
        bail!("no lock hierarchy declared: LOCK_HIERARCHY must name the lock tiers");
    }
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        for v in lint_source(&rel, &src) {
            violations.push((rel.clone(), v));
        }
    }
    Ok(TreeReport { files: files.len(), violations })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(violations: &[Violation]) -> Vec<&'static str> {
        violations.iter().map(|v| v.rule).collect()
    }

    // ---------------------------------------------------- lock-order

    #[test]
    fn lock_order_reentry_fires() {
        let src = r#"
fn f(pending: &std::sync::Mutex<u32>) {
    let a = pending.lock();
    let b = pending.lock();
}
"#;
        let v = lint_source("util/fixture.rs", src);
        assert_eq!(rules_of(&v), ["lock-order"], "{v:?}");
        assert_eq!(v[0].line, 4);
        assert!(v[0].message.contains("re-acquired while already held"), "{}", v[0].message);
    }

    #[test]
    fn lock_order_hierarchy_violation_fires() {
        // batcher (tier 1) is held; taking pending (tier 0) nests
        // upward — flagged.
        let src = r#"
fn f(pending: &M, batcher: &M) {
    let b = batcher.lock();
    let p = pending.lock();
}
"#;
        let v = lint_source("util/fixture.rs", src);
        assert_eq!(rules_of(&v), ["lock-order"], "{v:?}");
        assert!(v[0].message.contains("out of declared hierarchy order"), "{}", v[0].message);
    }

    #[test]
    fn lock_order_clean_nesting_passes() {
        // pending (tier 0) then batcher (tier 1): strictly downward.
        let src = r#"
fn f(pending: &M, batcher: &M) {
    let p = pending.lock();
    let b = batcher.lock();
}
"#;
        assert!(lint_source("util/fixture.rs", src).is_empty());
    }

    #[test]
    fn lock_order_churn_fires() {
        // The coordinator/server.rs:1181 shape: two adjacent statements
        // each taking and dropping the same lock.
        let src = r#"
fn f(queue_time: &std::sync::Mutex<Map>) {
    *queue_time.lock().entry(id).or_insert(0) += 1;
    queue_time.lock().remove(&id);
}
"#;
        let v = lint_source("util/fixture.rs", src);
        assert_eq!(rules_of(&v), ["lock-order"], "{v:?}");
        assert!(v[0].message.contains("re-acquired immediately after"), "{}", v[0].message);
    }

    #[test]
    fn lock_order_drop_releases_guard() {
        let src = r#"
fn f(pending: &std::sync::Mutex<u32>) {
    let a = pending.lock();
    drop(a);
    let b = pending.lock();
}
"#;
        assert!(lint_source("util/fixture.rs", src).is_empty());
    }

    // ------------------------------------------- blocking-under-lock

    #[test]
    fn blocking_under_lock_fires() {
        let src = r#"
fn f(pending: &std::sync::Mutex<u32>, rx: &Receiver<u32>) {
    let g = pending.lock();
    let msg = rx.recv();
}
"#;
        let v = lint_source("util/fixture.rs", src);
        assert_eq!(rules_of(&v), ["blocking-under-lock"], "{v:?}");
        assert!(v[0].message.contains("`recv()`"), "{}", v[0].message);
    }

    #[test]
    fn condvar_wait_is_exempt() {
        // Condvar::wait atomically releases the mutex — the blessed
        // blocking pattern must NOT be flagged.
        let src = r#"
fn f(m: &std::sync::Mutex<bool>, cv: &std::sync::Condvar) {
    let mut g = m.lock();
    g = cv.wait(g);
}
"#;
        assert!(lint_source("util/fixture.rs", src).is_empty());
    }

    #[test]
    fn blocking_after_release_passes() {
        let src = r#"
fn f(pending: &std::sync::Mutex<u32>, rx: &Receiver<u32>) {
    {
        let g = pending.lock();
    }
    let msg = rx.recv();
}
"#;
        assert!(lint_source("util/fixture.rs", src).is_empty());
    }

    // ---------------------------------------------- hot-path-unwrap

    #[test]
    fn hot_path_unwrap_fires_in_engine() {
        let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
"#;
        let v = lint_source("engine/fixture.rs", src);
        assert_eq!(rules_of(&v), ["hot-path-unwrap"], "{v:?}");
    }

    #[test]
    fn hot_path_expect_fires_in_coordinator() {
        let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.expect("always set")
}
"#;
        let v = lint_source("coordinator/fixture.rs", src);
        assert_eq!(rules_of(&v), ["hot-path-unwrap"], "{v:?}");
    }

    #[test]
    fn unwrap_outside_hot_path_passes() {
        let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
"#;
        assert!(lint_source("util/fixture.rs", src).is_empty());
    }

    #[test]
    fn unwrap_in_test_code_passes() {
        let src = r#"
#[test]
fn t() {
    let x: Option<u32> = Some(3);
    assert_eq!(x.unwrap(), 3);
}
"#;
        assert!(lint_source("engine/fixture.rs", src).is_empty());
    }

    // -------------------------------------------------- determinism

    #[test]
    fn determinism_hashmap_fires_in_sim() {
        let src = "use std::collections::HashMap;\n";
        let v = lint_source("sim/fixture.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
    }

    #[test]
    fn determinism_btreemap_passes_in_sim() {
        let src = "use std::collections::BTreeMap;\n";
        assert!(lint_source("sim/fixture.rs", src).is_empty());
    }

    #[test]
    fn determinism_instant_now_fires_in_sched() {
        let src = "fn f() -> u64 { tick(std::time::Instant::now()) }\n";
        let v = lint_source("sched/fixture.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
        assert!(v[0].message.contains("Instant::now()"), "{}", v[0].message);
    }

    #[test]
    fn determinism_float_eq_fires() {
        let src = "fn f(x: f64) -> bool { x == 0.5 }\n";
        let v = lint_source("engine/scheduler.rs", src);
        // engine/scheduler.rs is determinism-pinned by exact path.
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
    }

    #[test]
    fn determinism_rules_scoped_to_pinned_modules() {
        let src = "use std::collections::HashMap;\nfn f(x: f64) -> bool { x == 0.5 }\n";
        assert!(lint_source("coordinator/fixture.rs", src).is_empty());
    }

    #[test]
    fn determinism_fires_in_engine_migrate() {
        // The disagg DES models the MigrationHub's exact routing, so
        // engine/migrate.rs is determinism-pinned by exact path — but
        // its test module may stamp wall-clock carried state.
        let src = "fn f() -> u64 { tick(std::time::Instant::now()) }\n";
        let v = lint_source("engine/migrate.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
        let test_src = "#[cfg(test)]\nmod tests {\n    fn f() -> u64 { tick(std::time::Instant::now()) }\n}\n";
        assert!(lint_source("engine/migrate.rs", test_src).is_empty());
        assert!(lint_source("engine/core.rs", src).is_empty(), "scope is by exact path");
    }

    #[test]
    fn determinism_fires_in_engine_spec() {
        // The DES models draft agreement with the same pure function
        // the live SpecPair replays through, so engine/spec.rs is
        // determinism-pinned by exact path: ambient randomness or a
        // wall-clock read there would break the DES↔live
        // accepted/rejected-count pin.
        let src = "fn f() -> u64 { tick(std::time::Instant::now()) }\n";
        let v = lint_source("engine/spec.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
        let hash_src = "use std::collections::HashMap;\n";
        let v = lint_source("engine/spec.rs", hash_src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
        assert!(lint_source("engine/kv.rs", src).is_empty(), "scope is by exact path");
    }

    #[test]
    fn determinism_instant_now_fires_in_obs() {
        // The DES emits trace events through obs/ — wall-clock reads
        // there would silently de-determinize the shared tracing path.
        let src = "fn f() -> u64 { tick(std::time::Instant::now()) }\n";
        let v = lint_source("obs/recorder.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
        assert!(v[0].message.contains("Instant::now()"), "{}", v[0].message);
    }

    #[test]
    fn determinism_systemtime_now_fires_in_obs() {
        let src = "fn f() -> u64 { tick(std::time::SystemTime::now()) }\n";
        let v = lint_source("obs/registry.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
    }

    #[test]
    fn determinism_hashmap_fires_in_obs() {
        let src = "use std::collections::HashMap;\n";
        let v = lint_source("obs/fixture.rs", src);
        assert_eq!(rules_of(&v), ["determinism"], "{v:?}");
    }

    #[test]
    fn obs_clock_is_the_designated_wall_clock_exception() {
        // obs/clock.rs is the one obs file allowed to read the wall
        // clock — the Clock abstraction everything else goes through.
        let src = "fn f() -> u64 { tick(std::time::Instant::now()) }\n";
        assert!(lint_source("obs/clock.rs", src).is_empty());
    }

    // ---------------------------------------------------- annotations

    #[test]
    fn allow_annotation_suppresses_with_reason() {
        let src = r#"
fn f(x: Option<u32>) -> u32 {
    // cascadia-lint: allow(hot-path-unwrap, reason = "fixture: annotation grammar")
    x.unwrap()
}
"#;
        assert!(lint_source("engine/fixture.rs", src).is_empty());
    }

    #[test]
    fn allow_on_same_line_suppresses() {
        let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.unwrap() // cascadia-lint: allow(hot-path-unwrap, reason = "fixture: same line")
}
"#;
        assert!(lint_source("engine/fixture.rs", src).is_empty());
    }

    #[test]
    fn allow_without_reason_is_bad_annotation() {
        let src = r#"
fn f() {
    // cascadia-lint: allow(hot-path-unwrap)
    let x = 1;
}
"#;
        let v = lint_source("util/fixture.rs", src);
        assert_eq!(rules_of(&v), [BAD_ANNOTATION], "{v:?}");
    }

    #[test]
    fn allow_unknown_rule_is_bad_annotation() {
        let src = r#"
fn f() {
    // cascadia-lint: allow(made-up-rule, reason = "nope")
    let x = 1;
}
"#;
        let v = lint_source("util/fixture.rs", src);
        assert_eq!(rules_of(&v), [BAD_ANNOTATION], "{v:?}");
    }

    #[test]
    fn allow_does_not_suppress_other_rules() {
        // An allow for one rule must not blanket the line.
        let src = r#"
fn f(x: Option<u32>, q: f64) -> bool {
    // cascadia-lint: allow(determinism, reason = "fixture: wrong rule")
    x.unwrap() == 1
}
"#;
        let v = lint_source("engine/fixture.rs", src);
        assert_eq!(rules_of(&v), ["hot-path-unwrap"], "{v:?}");
    }

    // ------------------------------------------------ hierarchy gate

    #[test]
    #[allow(clippy::const_is_empty)] // asserting the declaration exists is the point
    fn lock_hierarchy_is_declared_and_ordered() {
        let pending = hierarchy_rank("pending");
        let batcher = hierarchy_rank("batcher");
        let queue_time = hierarchy_rank("queue_time");
        let first_tokens = hierarchy_rank("first_tokens");
        let policy = hierarchy_rank("policy");
        assert!(!LOCK_HIERARCHY.is_empty());
        assert!(pending.is_some() && batcher.is_some() && policy.is_some());
        assert!(pending < batcher, "pending is the outermost tier");
        assert!(batcher < queue_time, "batcher outranks the stats locks");
        assert_eq!(queue_time, first_tokens, "stats locks share a tier");
        assert!(queue_time < policy, "policy is the innermost tier");
        assert_eq!(hierarchy_rank("not_a_lock"), None);
    }

    // ------------------------------------------------- tree-clean gate

    /// THE enforcement point: plain `cargo test` lints the whole source
    /// tree. Re-introducing any violation (e.g. reverting the
    /// `coordinator/server.rs` queue_time double-lock fix) fails here.
    #[test]
    fn source_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = lint_tree(&root).expect("linting the source tree");
        assert!(report.files > 40, "walk found only {} files — wrong root?", report.files);
        assert!(
            report.violations.is_empty(),
            "cascadia-lint found {} violation(s):\n{}",
            report.violations.len(),
            report.render().join("\n")
        );
    }
}
