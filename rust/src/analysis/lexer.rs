//! A hand-rolled token-level Rust lexer for `cascadia-lint`.
//!
//! This is NOT a full Rust lexer — it is exactly the subset the lint
//! rules need: it must never mis-classify a comment, string, or char
//! literal as code (so lint patterns inside fixtures and messages stay
//! invisible), and it must keep idents, punctuation, and literals
//! apart with correct line numbers. Handled: line comments, nested
//! block comments, strings with escapes, raw (and byte/raw-byte)
//! strings with `#` fences, raw identifiers, char-literal vs lifetime
//! disambiguation, numeric literals with float detection, and
//! greedy longest-match multi-character operators.
//!
//! `scripts/cascadia_lint_mirror.py` re-implements this lexer
//! one-to-one for toolchain-free environments; keep the two in
//! lockstep.

/// Token classification — only as fine-grained as the rules require.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    /// Any string literal (contents dropped — never linted).
    Str,
    /// Any char or byte-char literal (contents dropped).
    Char,
    Int,
    /// Distinguished from [`Kind::Int`] for the `f64 ==` rule.
    Float,
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    /// 1-based source line.
    pub line: usize,
}

/// A `//` line comment (directives never live in block comments).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Multi-character operators, longest first so greedy matching is a
/// simple linear scan.
const MULTI_OPS: [&str; 24] = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=", "&&",
    "||", "..", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens plus the line comments (for directive
/// extraction). Never fails: unrecognized bytes become 1-char puncts.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    let text_of = |from: usize, to: usize| -> String { chars[from..to].iter().collect() };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            comments.push(Comment { line, text: text_of(i, j) });
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw strings / raw byte strings (`r"`, `r#"`, `br#"`) and raw
        // identifiers (`r#ident`).
        if c == 'r' || c == 'b' {
            let mut j = i;
            if chars[j] == 'b' && j + 1 < n && chars[j + 1] == 'r' {
                j += 1;
            }
            if chars[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    let start_line = line;
                    k += 1;
                    while k < n {
                        if chars[k] == '\n' {
                            line += 1;
                            k += 1;
                        } else if chars[k] == '"' && fence_closes(&chars, k, hashes) {
                            k += 1 + hashes;
                            break;
                        } else {
                            k += 1;
                        }
                    }
                    toks.push(Token { kind: Kind::Str, text: String::new(), line: start_line });
                    i = k;
                    continue;
                }
                if hashes == 1 && k < n && is_ident_start(chars[k]) {
                    let mut m = k;
                    while m < n && is_ident_char(chars[m]) {
                        m += 1;
                    }
                    toks.push(Token { kind: Kind::Ident, text: text_of(k, m), line });
                    i = m;
                    continue;
                }
            }
        }
        // Byte char literal b'x'.
        if c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
            let mut j = i + 2;
            if j < n && chars[j] == '\\' {
                j += 2;
            } else {
                j += 1;
            }
            while j < n && chars[j] != '\'' {
                j += 1;
            }
            toks.push(Token { kind: Kind::Char, text: String::new(), line });
            i = j + 1;
            continue;
        }
        // Plain (or byte) string literal.
        if c == '"' || (c == 'b' && i + 1 < n && chars[i + 1] == '"') {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            let start_line = line;
            while j < n {
                if chars[j] == '\\' {
                    j += 2;
                } else if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            toks.push(Token { kind: Kind::Str, text: String::new(), line: start_line });
            i = j;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                let mut j = i + 3; // skip the escaped char
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
                toks.push(Token { kind: Kind::Char, text: String::new(), line });
                i = j + 1;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                toks.push(Token { kind: Kind::Char, text: String::new(), line });
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && is_ident_char(chars[j]) {
                j += 1;
            }
            toks.push(Token { kind: Kind::Lifetime, text: text_of(i, j), line });
            i = j;
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_char(chars[j]) {
                j += 1;
            }
            toks.push(Token { kind: Kind::Ident, text: text_of(i, j), line });
            i = j;
            continue;
        }
        // Numeric literal. A `.` is consumed only when a digit follows
        // (so `0..n` and tuple indexing stay separate tokens); exponents
        // and a consumed `.` mark floats, except in hex literals.
        if c.is_ascii_digit() {
            let is_hex = c == '0' && i + 1 < n && (chars[i + 1] == 'x' || chars[i + 1] == 'X');
            let mut is_float = false;
            let mut j = i;
            while j < n {
                let d = chars[j];
                if d.is_alphanumeric() || d == '_' {
                    if !is_hex
                        && (d == 'e' || d == 'E')
                        && j + 1 < n
                        && (chars[j + 1] == '+' || chars[j + 1] == '-')
                    {
                        is_float = true;
                        j += 2;
                        continue;
                    }
                    j += 1;
                } else if d == '.' && j + 1 < n && chars[j + 1].is_ascii_digit() {
                    is_float = true;
                    j += 1;
                } else {
                    break;
                }
            }
            let text = text_of(i, j);
            if !is_hex && (text.contains('e') || text.contains('E')) && !text.contains('x') {
                is_float = true;
            }
            let kind = if is_float { Kind::Float } else { Kind::Int };
            toks.push(Token { kind, text, line });
            i = j;
            continue;
        }
        // Punctuation: greedy longest-match against the operator table.
        let mut matched: Option<&str> = None;
        for op in MULTI_OPS {
            if starts_with_at(&chars, i, op) {
                matched = Some(op);
                break;
            }
        }
        if let Some(op) = matched {
            toks.push(Token { kind: Kind::Punct, text: op.to_string(), line });
            i += op.chars().count();
        } else {
            toks.push(Token { kind: Kind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    (toks, comments)
}

/// Does `"` at `chars[k]` close a raw string fenced by `hashes` hashes?
fn fence_closes(chars: &[char], k: usize, hashes: usize) -> bool {
    if k + hashes >= chars.len() {
        return false;
    }
    chars[k + 1..=k + hashes].iter().all(|&h| h == '#')
}

fn starts_with_at(chars: &[char], i: usize, op: &str) -> bool {
    let ops: Vec<char> = op.chars().collect();
    if i + ops.len() > chars.len() {
        return false;
    }
    chars[i..i + ops.len()] == ops[..]
}
