//! # Cascadia
//!
//! A cascade serving system for large language models — a full
//! reproduction of *"Cascadia: An Efficient Cascade Serving System for
//! Large Language Models"* (CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas stack.
//!
//! Cascadia routes every request through a model cascade under a
//! pluggable [`router::RoutingPolicy`] — per-tier score thresholds,
//! length-predictive entry, or margin/hysteresis escalation — scored
//! by a judger at every tier. The headline contribution is a
//! **bi-level scheduler**:
//!
//! * the **inner level** ([`sched::inner`]) solves a mixed-integer
//!   linear program ([`milp`]) to pick GPU allocations and parallelism
//!   strategies ([`parallel`]) per model tier, driven by the latency
//!   simulator ([`sim`]) over the analytic cost model ([`perf`]);
//! * the **outer level** ([`sched::outer`]) runs a weighted Tchebycheff
//!   sweep over the routing policy's parameter space to trace the
//!   latency/quality Pareto front and pick the plan meeting the user's
//!   quality requirement.
//!
//! The serving engine ([`coordinator`]) executes the chosen
//! [`sched::plan::CascadePlan`] — the single schedule→serve artifact,
//! JSON round-trippable into `ServerConfig::from_plan` /
//! `TcpFrontend::from_plan`: policy routing ([`router`]), continuous
//! batching, and escalation. Worker inner loops can run in whole-batch
//! lockstep or through the continuous-batching execution engine
//! ([`engine`]): iteration-granular admission/retirement against a
//! paged KV-cache pool sized from the same [`perf`] memory terms the
//! scheduler optimizes. The online adaptation subsystem
//! ([`adapt`]) closes the §4.4 loop at runtime: every admitted request
//! feeds the workload monitor, a detected shift re-runs the bi-level
//! scheduler (with a precomputed-plan cache for repeat regimes), and
//! the new plan is hot-swapped into the running server without
//! dropping in-flight requests. Real model execution goes through
//! [`runtime`], which loads the AOT-compiled HLO-text artifacts
//! produced by `python/compile/aot.py` — Python never runs on the
//! request path.
//!
//! See `DESIGN.md` for the system inventory and the paper-experiment
//! index, and `examples/` for runnable entry points.

pub mod adapt;
pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod harness;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod judge;
pub mod metrics;
pub mod milp;
pub mod models;
pub mod obs;
pub mod parallel;
pub mod perf;
pub mod report;
pub mod router;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
pub mod workload;
