//! Figure 2: benchmarked throughput of different parallelism
//! strategies across workloads and model sizes — the motivation for
//! workload-aware strategy search (up to ~3x spread).
//!
//! For each (model, workload) pair we report simulated throughput of
//! the classic 8-GPU strategy grid in the paper's (DP, TP, PP)
//! notation, plus the best/worst ratio.
//!
//! Usage: fig2_parallelism [--gpus 8] [--n 1500] [--out results/fig2.csv]

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::models::{deepseek_cascade, ModelSpec};
use cascadia::parallel::{design_feasible, Strategy};
use cascadia::perf::{ReplicaModel, Workload};
use cascadia::report::Table;
use cascadia::sim::des::{simulate, SimRequest};
use cascadia::util::cli::Args;
use cascadia::util::rng::Rng;

fn replicas(model: &ModelSpec, cluster: &ClusterSpec, s: &Strategy, ctx: f64) -> Vec<ReplicaModel> {
    s.groups
        .iter()
        .flat_map(|g| (0..g.count).map(|_| ReplicaModel::new(model, cluster, g.tp, g.pp, ctx)))
        .collect()
}

/// Saturated throughput: offer 3x the pool's capacity and measure
/// completed/makespan.
fn throughput(model: &ModelSpec, cluster: &ClusterSpec, s: &Strategy, w: &Workload, n: usize) -> f64 {
    let ctx = w.avg_input + w.avg_output / 2.0;
    let pool = replicas(model, cluster, s, ctx);
    if pool.iter().all(|r| r.max_batch == 0) {
        return 0.0;
    }
    let cap: f64 = pool.iter().map(|r| r.capacity(w)).sum();
    let rate = (cap * 3.0).max(0.5);
    let mut rng = Rng::new(42);
    let mut t = 0.0;
    let trace: Vec<SimRequest> = (0..n)
        .map(|_| {
            t += rng.exp(rate);
            SimRequest {
                arrival: t,
                input_tokens: w.avg_input as u32,
                output_tokens: w.avg_output as u32,
            }
        })
        .collect();
    simulate(&pool, &trace).throughput_rps
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 8)?;
    let n = args.usize_or("n", 1500)?;
    let out = args.str_or("out", "results/fig2.csv");

    let cluster = ClusterSpec::paper_testbed();
    let cascade = deepseek_cascade();
    let models = [&cascade[0], &cascade[1]]; // 7B and 70B, like the figure

    // The figure's two workloads: short vs long outputs.
    let workloads = [
        ("short-out(512)", Workload { rate: 0.0, avg_input: 512.0, avg_output: 512.0 }),
        ("long-out(1024)", Workload { rate: 0.0, avg_input: 512.0, avg_output: 1024.0 }),
    ];

    // (DP, TP, PP) grid over `gpus` GPUs.
    let combos: Vec<(usize, usize, usize)> = vec![
        (gpus, 1, 1),
        (gpus / 2, 2, 1),
        (gpus / 4, 4, 1),
        (1, gpus.min(8), 1),
        (gpus / 2, 1, 2),
        (gpus / 4, 2, 2),
        (gpus / 4, 1, 4),
        (1, gpus / 2, 2),
    ];

    let mut table = Table::new(
        "Figure 2 — throughput by parallelism strategy (req/s)",
        &["model", "workload", "(DP,TP,PP)", "throughput", "feasible"],
    );

    for model in models {
        for (wname, w) in &workloads {
            let mut best: f64 = 0.0;
            let mut worst = f64::INFINITY;
            for &(dp, tp, pp) in &combos {
                if dp == 0 || tp * pp * dp > gpus {
                    continue;
                }
                let feasible = design_feasible(model, &cluster, tp, pp);
                let thr = if feasible {
                    let s = Strategy::uniform(tp, pp, dp);
                    throughput(model, &cluster, &s, w, n)
                } else {
                    0.0
                };
                if feasible && thr > 0.0 {
                    best = best.max(thr);
                    worst = worst.min(thr);
                }
                table.row(vec![
                    model.name.to_string(),
                    wname.to_string(),
                    format!("({dp},{tp},{pp})"),
                    format!("{thr:.2}"),
                    feasible.to_string(),
                ]);
            }
            if worst.is_finite() && worst > 0.0 {
                table.row(vec![
                    model.name.to_string(),
                    wname.to_string(),
                    "best/worst".into(),
                    format!("{:.2}x", best / worst),
                    "-".into(),
                ]);
            }
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
