//! Figure 12: scheduling-algorithm runtime vs cluster size (32 / 64 /
//! 128 GPUs). The paper reports ~1 min at 32 GPUs and ~2/4 min at
//! 64/128 on a 12-core box; this harness reports our wall-clock on the
//! current machine plus the MILP/enumeration breakdown.
//!
//! Usage: fig12_sched_runtime [--sizes 32,64,128] [--n 800]
//!                            [--out results/fig12.csv]

use std::time::Instant;

use anyhow::Result;
use cascadia::harness::Scenario;
use cascadia::models::deepseek_cascade;
use cascadia::report::Table;
use cascadia::sched::inner::{InnerOptions, InnerSolver};
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;
use cascadia::workload::estimate_stats;

fn main() -> Result<()> {
    let args = Args::from_env();
    let sizes: Vec<usize> = args
        .str_or("sizes", "32,64,128")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let n = args.usize_or("n", 800)?;
    let out = args.str_or("out", "results/fig12.csv");

    let cascade = deepseek_cascade();
    let mut table = Table::new(
        "Figure 12 — scheduler runtime by cluster size",
        &["gpus", "full-sweep(s)", "one-inner-solve(s)", "explored", "pareto"],
    );

    for &gpus in &sizes {
        // Rate scales with cluster size to keep utilization comparable.
        let rate = 6.0 * gpus as f64 / 32.0;
        let scenario = Scenario::new(cascade.clone(), gpus, 1, rate, n, 31);
        let opts = OuterOptions::default();

        let (sweep, secs) = scenario.schedule(&opts)?;

        // One cold inner solve (tables + MILP) for the breakdown.
        let stats = estimate_stats(&scenario.plan_reqs);
        let w = stats.workload();
        let tier_w = vec![w, w.scaled(0.5), w.scaled(0.2)];
        let solver = InnerSolver::new(
            cascade.clone(),
            scenario.cluster.clone(),
            InnerOptions::default(),
        );
        let t0 = Instant::now();
        let _ = solver.solve(&tier_w, gpus)?;
        let inner_secs = t0.elapsed().as_secs_f64();

        table.row(vec![
            gpus.to_string(),
            format!("{secs:.2}"),
            format!("{inner_secs:.2}"),
            sweep.explored.len().to_string(),
            sweep.pareto.len().to_string(),
        ]);
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
