//! Figure 1: average response quality vs single-request latency of the
//! DeepSeek models.
//!
//! Quality = mean judged score on the mid-complexity trace; latency =
//! single-request (batch-1) service time under each model's best
//! single-replica design on one 8-GPU server, matching the figure's
//! "bigger is better but slower" framing.
//!
//! Usage: fig1_quality_latency [--trace 2] [--n 2000] [--out results/fig1.csv]

use anyhow::Result;
use cascadia::cluster::ClusterSpec;
use cascadia::judge::Judger;
use cascadia::models::deepseek_cascade;
use cascadia::perf::{ReplicaModel, Workload};
use cascadia::report::{fmt_secs, Table};
use cascadia::sched::inner::best_strategy_for;
use cascadia::util::cli::Args;
use cascadia::workload::{generate, paper_trace};

fn main() -> Result<()> {
    let args = Args::from_env();
    let trace_idx = args.usize_or("trace", 2)?;
    let n = args.usize_or("n", 2000)?;
    let out = args.str_or("out", "results/fig1.csv");

    let cascade = deepseek_cascade();
    let cluster = ClusterSpec::paper_testbed();
    let judger = Judger::new(0);
    let reqs = generate(&paper_trace(trace_idx, 1.0), n, 1);

    let mut table = Table::new(
        "Figure 1 — quality vs latency (DeepSeek models)",
        &["model", "quality(judged)", "latency(1-req)", "strategy"],
    );

    for (tier, model) in cascade.iter().enumerate() {
        let quality: f64 =
            reqs.iter().map(|r| judger.score(model, r, tier)).sum::<f64>() / reqs.len() as f64;
        // Best single-replica design on one server (8 GPUs), batch 1.
        let w = Workload { rate: 0.1, avg_input: 512.0, avg_output: 256.0 };
        let (strategy, _) = best_strategy_for(model, &cluster, 8, &w, false)
            .expect("one server fits every model at INT4/bf16");
        let g = &strategy.groups[0];
        let rm = ReplicaModel::new(model, &cluster, g.tp, g.pp, 640.0);
        let latency = rm.prefill_latency(512.0) + 256.0 * rm.decode_iteration(1);
        table.row(vec![
            model.name.to_string(),
            format!("{quality:.1}"),
            fmt_secs(latency),
            strategy.label(),
        ]);
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
