//! Figure 8: end-to-end throughput (requests/s) of the three systems
//! across traces × quality requirements. Same planning protocol as
//! Figure 7; throughput is completed-requests / makespan on the
//! held-out trace at a saturating arrival rate.
//!
//! Usage: fig8_throughput [--cascade deepseek] [--gpus 32] [--n 1500]
//!                        [--saturate 3.0] [--out results/fig8.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario};
use cascadia::models::cascade_by_name;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;
use cascadia::workload::{generate, paper_trace};

fn main() -> Result<()> {
    let args = Args::from_env();
    let cascade_name = args.str_or("cascade", "deepseek");
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1500)?;
    let saturate = args.f64_or("saturate", 3.0)?;
    let out = args.str_or("out", "results/fig8.csv");

    let cascade = cascade_by_name(&cascade_name)
        .ok_or_else(|| anyhow::anyhow!("unknown cascade {cascade_name}"))?;
    let opts = OuterOptions::default();

    let mut table = Table::new(
        &format!("Figure 8 — throughput (req/s), {cascade_name}, {gpus} GPUs"),
        &["trace", "quality", "system", "throughput", "tokens/s", "quality(measured)"],
    );

    for trace in [1usize, 2, 3] {
        let rate = default_rate(trace);
        let scenario = Scenario::new(cascade.clone(), gpus, trace, rate, n, 11);
        // Saturating evaluation trace: same mix at `saturate`x the rate.
        let sat_spec = paper_trace(trace, rate * saturate);
        let sat_reqs = generate(&sat_spec, n, 13);

        for q in [90.0, 85.0, 80.0, 70.0] {
            let systems: Vec<(&str, anyhow::Result<_>)> = vec![
                ("cascadia", scenario.cascadia_plan(q, &opts)),
                ("standalone", scenario.standalone_plan(q)),
                ("cascadeserve", scenario.cascade_serve_plan(q)),
            ];
            for (name, plan) in systems {
                let row = match plan.and_then(|p| {
                    cascadia::coordinator::simulate_cascade(
                        &p,
                        &scenario.cascade,
                        &scenario.cluster,
                        &scenario.judger,
                        &sat_reqs,
                    )
                }) {
                    Ok(sim) => {
                        let toks: f64 = sim
                            .tier_outcomes
                            .iter()
                            .flatten()
                            .map(|o| o.tokens_per_sec)
                            .sum();
                        vec![
                            format!("trace{trace}"),
                            format!("{q:.0}"),
                            name.to_string(),
                            format!("{:.2}", sim.throughput_rps),
                            format!("{toks:.0}"),
                            format!("{:.1}", sim.quality),
                        ]
                    }
                    Err(e) => vec![
                        format!("trace{trace}"),
                        format!("{q:.0}"),
                        name.to_string(),
                        "-".into(),
                        "-".into(),
                        format!("({e})"),
                    ],
                };
                table.row(row);
            }
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
