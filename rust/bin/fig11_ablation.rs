//! Figure 11: ablation study — disable (i) parallelism-strategy
//! optimization (uniform TP-in-server/DP-across) or (ii) resource
//! allocation optimization (uniform split), measure the latency hit.
//!
//! The paper reports up to 1.6x (1.4x avg) for (i) and up to 2.1x
//! (1.7x avg) for (ii).
//!
//! Usage: fig11_ablation [--gpus 32] [--n 1200] [--out results/fig11.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario, PAPER_CASES};
use cascadia::models::deepseek_cascade;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1200)?;
    let out = args.str_or("out", "results/fig11.csv");

    let cascade = deepseek_cascade();

    let variants: [(&str, fn(&mut OuterOptions)); 3] = [
        ("cascadia", |_| {}),
        ("uniform-parallelism", |o| o.inner.uniform_parallelism = true),
        ("uniform-allocation", |o| o.inner.uniform_allocation = true),
    ];

    let mut table = Table::new(
        "Figure 11 — ablations (p95 latency on held-out trace)",
        &["case", "variant", "p95(s)", "slowdown", "quality"],
    );

    let mut slowdowns: Vec<(String, f64)> = Vec::new();

    for (q, trace) in PAPER_CASES {
        let scenario =
            Scenario::new(cascade.clone(), gpus, trace, default_rate(trace), n, 29);
        let mut base_p95: Option<f64> = None;
        for (name, tweak) in variants {
            let mut opts = OuterOptions::default();
            tweak(&mut opts);
            let row = match scenario
                .cascadia_plan(q, &opts)
                .and_then(|p| scenario.evaluate(&p))
            {
                Ok(sim) => {
                    let p95 = sim.p95();
                    let slowdown = match base_p95 {
                        None => {
                            base_p95 = Some(p95);
                            1.0
                        }
                        Some(b) => p95 / b.max(1e-9),
                    };
                    if name != "cascadia" {
                        slowdowns.push((name.to_string(), slowdown));
                    }
                    vec![
                        format!("({q:.0},{trace})"),
                        name.to_string(),
                        format!("{p95:.2}"),
                        format!("{slowdown:.2}x"),
                        format!("{:.1}", sim.quality),
                    ]
                }
                Err(e) => vec![
                    format!("({q:.0},{trace})"),
                    name.to_string(),
                    "-".into(),
                    "-".into(),
                    format!("({e})"),
                ],
            };
            table.row(row);
        }
    }

    // Aggregates per variant.
    for variant in ["uniform-parallelism", "uniform-allocation"] {
        let v: Vec<f64> = slowdowns
            .iter()
            .filter(|(n, _)| n == variant)
            .map(|(_, s)| *s)
            .collect();
        if !v.is_empty() {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            let max = v.iter().cloned().fold(0.0f64, f64::max);
            table.row(vec![
                "ALL".into(),
                variant.to_string(),
                "-".into(),
                format!("avg {avg:.2}x / max {max:.2}x"),
                "-".into(),
            ]);
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
