//! Figure 10: per-tier processing latency across the paper's test
//! cases — showing that Cascadia's co-optimization keeps the tiers'
//! loads balanced (no tier's latency dominates).
//!
//! Usage: fig10_balance [--gpus 32] [--n 1200] [--out results/fig10.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario, PAPER_CASES};
use cascadia::models::deepseek_cascade;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;
use cascadia::util::stats;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1200)?;
    let out = args.str_or("out", "results/fig10.csv");

    let cascade = deepseek_cascade();
    let opts = OuterOptions::default();

    let mut table = Table::new(
        "Figure 10 — per-tier mean processing latency (s) by test case",
        &["case", "tier", "model", "mean(s)", "p95(s)", "visits", "balance(max/min)"],
    );

    for (q, trace) in PAPER_CASES {
        let scenario =
            Scenario::new(cascade.clone(), gpus, trace, default_rate(trace), n, 23);
        let plan = match scenario.cascadia_plan(q, &opts) {
            Ok(p) => p,
            Err(e) => {
                table.row(vec![
                    format!("({q:.0},{trace})"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("({e})"),
                ]);
                continue;
            }
        };
        let sim = scenario.evaluate(&plan)?;
        let mut tier_means = Vec::new();
        for (t, outcome) in sim.tier_outcomes.iter().enumerate() {
            let Some(o) = outcome else { continue };
            let mean = o.mean();
            tier_means.push(mean);
            table.row(vec![
                format!("({q:.0},{trace})"),
                format!("c{}", t + 1),
                cascade[t].name.to_string(),
                format!("{mean:.2}"),
                format!("{:.2}", stats::percentile(&o.latencies, 0.95)),
                format!("{}", o.latencies.len()),
                String::new(),
            ]);
        }
        if tier_means.len() > 1 {
            let max = tier_means.iter().cloned().fold(0.0f64, f64::max);
            let min = tier_means.iter().cloned().fold(f64::INFINITY, f64::min);
            table.row(vec![
                format!("({q:.0},{trace})"),
                "-".into(),
                "BALANCE".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("{:.2}x", max / min.max(1e-9)),
            ]);
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
