//! Table 2: chosen parallelism strategies (s1, s2, s3) per test case,
//! in the paper's notation — e.g. `s2: (DP=2, TP=4)` or mixed sets
//! like `s3: (TP=4, PP=3), (TP=8)`.
//!
//! Usage: table2_parallelism [--gpus 32] [--n 1200] [--out results/table2.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario, PAPER_CASES};
use cascadia::models::deepseek_cascade;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1200)?;
    let out = args.str_or("out", "results/table2.csv");

    let cascade = deepseek_cascade();
    let opts = OuterOptions::default();

    let mut table = Table::new(
        "Table 2 — parallelism strategies per test case",
        &["case", "s1", "s2", "s3"],
    );

    for (q, trace) in PAPER_CASES {
        let scenario =
            Scenario::new(cascade.clone(), gpus, trace, default_rate(trace), n, 43);
        match scenario.cascadia_plan(q, &opts) {
            Ok(plan) => {
                let s: Vec<String> = plan
                    .tiers
                    .iter()
                    .map(|t| {
                        t.strategy
                            .as_ref()
                            .map(|s| s.label())
                            .unwrap_or_else(|| "-".to_string())
                    })
                    .collect();
                table.row(vec![
                    format!("({q:.0},{trace})"),
                    s[0].clone(),
                    s[1].clone(),
                    s.get(2).cloned().unwrap_or_else(|| "-".into()),
                ]);
            }
            Err(e) => {
                table.row(vec![
                    format!("({q:.0},{trace})"),
                    format!("({e})"),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
