//! Figure 7: end-to-end SLO attainment — Cascadia vs stand-alone
//! (SGLang-style) vs CascadeServe-like, across traces × quality
//! requirements.
//!
//! For each (trace, quality) cell the three systems are planned on the
//! planning trace, evaluated on a held-out trace, and the attainment
//! curve over SLO scales is printed, plus the headline "min scale at
//! 95% attainment" (the paper's stars).
//!
//! Usage: fig7_slo [--cascade deepseek] [--gpus 32] [--n 1500]
//!                 [--traces 1,2,3] [--qualities 90,85,80,70]
//!                 [--out results/fig7.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, slo_unit, Scenario};
use cascadia::metrics::{default_scales, SloCurve};
use cascadia::models::cascade_by_name;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cascade_name = args.str_or("cascade", "deepseek");
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1500)?;
    let out = args.str_or("out", "results/fig7.csv");
    let traces: Vec<usize> = args
        .str_or("traces", "1,2,3")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let qualities: Vec<f64> = args
        .str_or("qualities", "90,85,80,70")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    let cascade = cascade_by_name(&cascade_name)
        .ok_or_else(|| anyhow::anyhow!("unknown cascade {cascade_name}"))?;
    let opts = OuterOptions::default();
    let scales = default_scales();

    let mut table = Table::new(
        &format!("Figure 7 — min SLO scale @95% attainment ({cascade_name}, {gpus} GPUs)"),
        &["trace", "quality", "system", "minScale@95%", "p95(s)", "quality(measured)"],
    );

    for &trace in &traces {
        let scenario = Scenario::new(
            cascade.clone(),
            gpus,
            trace,
            default_rate(trace),
            n,
            7,
        );
        for &q in &qualities {
            let systems: Vec<(&str, anyhow::Result<_>)> = vec![
                ("cascadia", scenario.cascadia_plan(q, &opts)),
                ("standalone", scenario.standalone_plan(q)),
                ("cascadeserve", scenario.cascade_serve_plan(q)),
            ];
            // One SLO unit per cell, from the first system that planned.
            let mut unit: Option<f64> = None;
            for (name, plan) in systems {
                let row = match plan.and_then(|p| {
                    let sim = scenario.evaluate(&p)?;
                    let u = match unit {
                        Some(u) => u,
                        None => {
                            let u = slo_unit(&scenario, &p)?;
                            unit = Some(u);
                            u
                        }
                    };
                    Ok((sim, u))
                }) {
                    Ok((sim, u)) => {
                        let scale = SloCurve::exact_scale(&sim.e2e_latencies, u, 0.95);
                        vec![
                            format!("trace{trace}"),
                            format!("{q:.0}"),
                            name.to_string(),
                            format!("{scale:.2}"),
                            format!("{:.2}", sim.p95()),
                            format!("{:.1}", sim.quality),
                        ]
                    }
                    Err(e) => vec![
                        format!("trace{trace}"),
                        format!("{q:.0}"),
                        name.to_string(),
                        "-".into(),
                        "-".into(),
                        format!("({e})"),
                    ],
                };
                table.row(row);
            }
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");

    // Attainment-curve CSV for plotting (per system at q=qualities[0]).
    let _ = scales;
    Ok(())
}
