//! Figures 6/13: the explored scheduling points and Pareto-optimal
//! front per trace, from systematically varying thresholds (h1, h2)
//! and the Tchebycheff weights (λ1, λ2).
//!
//! Emits all explored (latency, quality) points plus the front and the
//! per-λ Tchebycheff winners; results/fig13_traceN.csv can be plotted
//! directly.
//!
//! Usage: fig13_pareto [--gpus 32] [--n 1200] [--out-dir results]

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario};
use cascadia::models::deepseek_cascade;
use cascadia::report::Table;
use cascadia::router::RoutingPolicy;
use cascadia::sched::outer::{tchebycheff_winners, OuterOptions};
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1200)?;
    let out_dir = args.str_or("out-dir", "results");

    let cascade = deepseek_cascade();
    let opts = OuterOptions::default();

    for trace in [1usize, 2, 3] {
        let scenario =
            Scenario::new(cascade.clone(), gpus, trace, default_rate(trace), n, 37);
        let (sweep, secs) = scenario.schedule(&opts)?;
        let winners = tchebycheff_winners(&sweep, &opts);

        let mut table = Table::new(
            &format!(
                "Figure 13 — trace {trace}: explored={} pareto={} winners={} ({secs:.1}s, utopia L={:.2}s Q={:.1})",
                sweep.explored.len(),
                sweep.pareto.len(),
                winners.len(),
                sweep.utopia.0,
                sweep.utopia.1
            ),
            &["kind", "latency(s)", "quality", "h1", "h2"],
        );
        for (kind, points) in [
            ("explored", &sweep.explored),
            ("pareto", &sweep.pareto),
            ("tcheby", &winners),
        ] {
            for p in points {
                let h = p.plan.policy.thresholds();
                table.row(vec![
                    kind.to_string(),
                    format!("{:.3}", p.latency),
                    format!("{:.2}", p.quality),
                    format!("{:.0}", h.first().copied().unwrap_or(0.0)),
                    format!("{:.0}", h.get(1).copied().unwrap_or(0.0)),
                ]);
            }
        }
        // Print only the front + winners to stdout (explored is large).
        let mut short = Table::new(
            &format!("trace {trace} Pareto front"),
            &["latency(s)", "quality", "policy"],
        );
        for p in &sweep.pareto {
            short.row(vec![
                format!("{:.3}", p.latency),
                format!("{:.2}", p.quality),
                p.plan.policy.label(),
            ]);
        }
        print!("{}", short.render());
        let path = format!("{out_dir}/fig13_trace{trace}.csv");
        table.write_csv(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}
