//! Table 1: thresholds (h1, h2), processing ratios (p1, p2, p3) and
//! allocated resources (f1, f2, f3) per test case.
//!
//! The expected *shape* vs the paper: lower quality requirements give
//! lower thresholds, smaller large-tier ratios/allocations, and the
//! easy trace 3 drops the largest tier entirely.
//!
//! Usage: table1_case_study [--gpus 32] [--n 1200] [--out results/table1.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, Scenario, PAPER_CASES};
use cascadia::models::deepseek_cascade;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1200)?;
    let out = args.str_or("out", "results/table1.csv");

    let cascade = deepseek_cascade();
    let opts = OuterOptions::default();

    let mut table = Table::new(
        "Table 1 — thresholds, processing ratios, allocations",
        &["case", "h1", "h2", "p1", "p2", "p3", "f1", "f2", "f3", "L(s)", "Q"],
    );

    for (q, trace) in PAPER_CASES {
        let scenario =
            Scenario::new(cascade.clone(), gpus, trace, default_rate(trace), n, 41);
        match scenario.cascadia_plan(q, &opts) {
            Ok(plan) => {
                let h = plan.policy.thresholds();
                let p: Vec<f64> =
                    plan.tiers.iter().map(|t| t.processing_ratio * 100.0).collect();
                let f: Vec<usize> = plan.tiers.iter().map(|t| t.gpus).collect();
                table.row(vec![
                    format!("({q:.0},{trace})"),
                    format!("{:.0}", h[0]),
                    format!("{:.0}", h.get(1).copied().unwrap_or(0.0)),
                    format!("{:.0}%", p[0]),
                    format!("{:.0}%", p[1]),
                    format!("{:.0}%", p.get(2).copied().unwrap_or(0.0)),
                    f[0].to_string(),
                    f[1].to_string(),
                    f.get(2).copied().unwrap_or(0).to_string(),
                    format!("{:.2}", plan.predicted_latency),
                    format!("{:.1}", plan.predicted_quality),
                ]);
            }
            Err(e) => {
                table.row(vec![
                    format!("({q:.0},{trace})"),
                    "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                    "-".into(), "-".into(), "-".into(), "-".into(),
                    format!("({e})"),
                ]);
            }
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
