//! Figure 9: SLO attainment with the Llama cascade (Llama3-8B ->
//! Llama3-70B) — Cascadia generalizes across model families.
//!
//! This is Figure 7's protocol with `--cascade llama` and quality
//! requirements adapted to the two-tier Llama range.
//!
//! Usage: fig9_llama [--gpus 32] [--n 1500] [--out results/fig9.csv]

use anyhow::Result;
use cascadia::harness::{default_rate, slo_unit, Scenario};
use cascadia::metrics::SloCurve;
use cascadia::models::llama_cascade;
use cascadia::report::Table;
use cascadia::sched::outer::OuterOptions;
use cascadia::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 32)?;
    let n = args.usize_or("n", 1500)?;
    let out = args.str_or("out", "results/fig9.csv");

    let cascade = llama_cascade();
    let opts = OuterOptions::default();

    let mut table = Table::new(
        &format!("Figure 9 — Llama cascade, min SLO scale @95% ({gpus} GPUs)"),
        &["trace", "quality", "system", "minScale@95%", "p95(s)", "quality(measured)"],
    );

    for trace in [1usize, 2, 3] {
        let scenario =
            Scenario::new(cascade.clone(), gpus, trace, default_rate(trace), n, 17);
        for q in [82.0, 78.0, 72.0] {
            let systems: Vec<(&str, anyhow::Result<_>)> = vec![
                ("cascadia", scenario.cascadia_plan(q, &opts)),
                ("standalone", scenario.standalone_plan(q)),
                ("cascadeserve", scenario.cascade_serve_plan(q)),
            ];
            let mut unit: Option<f64> = None;
            for (name, plan) in systems {
                let row = match plan.and_then(|p| {
                    let sim = scenario.evaluate(&p)?;
                    let u = match unit {
                        Some(u) => u,
                        None => {
                            let u = slo_unit(&scenario, &p)?;
                            unit = Some(u);
                            u
                        }
                    };
                    Ok((sim, u))
                }) {
                    Ok((sim, u)) => {
                        let scale = SloCurve::exact_scale(&sim.e2e_latencies, u, 0.95);
                        vec![
                            format!("trace{trace}"),
                            format!("{q:.0}"),
                            name.to_string(),
                            format!("{scale:.2}"),
                            format!("{:.2}", sim.p95()),
                            format!("{:.1}", sim.quality),
                        ]
                    }
                    Err(e) => vec![
                        format!("trace{trace}"),
                        format!("{q:.0}"),
                        name.to_string(),
                        "-".into(),
                        "-".into(),
                        format!("({e})"),
                    ],
                };
                table.row(row);
            }
        }
    }

    print!("{}", table.render());
    table.write_csv(&out)?;
    println!("wrote {out}");
    Ok(())
}
